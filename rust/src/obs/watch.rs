//! `train --watch`: a live status ticker over the metrics registry and
//! fleet telemetry store (DESIGN.md §8).
//!
//! A background thread wakes on a wall-time cadence, freezes
//! [`crate::obs::metrics::snapshot`] + [`crate::obs::telemetry::fleet`],
//! and renders one status line to stderr — epoch, latest eval error,
//! fleet utilization, wire bytes, and per-worker RTT — plus (when a
//! path is given) one JSON object per tick appended to `status.jsonl`,
//! so a running sweep stops being a black box.
//!
//! The ticker is read-only: it never writes a metric, never touches a
//! clock the trainer can see, and is started only when the caller has
//! already decided observability is on — so the obs-on ≡ obs-off
//! bit-exactness pin holds with or without `--watch`.

use crate::ser::Value;
use std::io::Write;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Build one status tick as a JSON object from the current registry +
/// fleet state. Pure read; used by both render targets and the tests.
pub fn status_value() -> Value {
    let snap = crate::obs::metrics::snapshot();
    let f = |section: &str, name: &str| snap.get(section).and_then(|s| s.get_f64(name));
    let compute = f("sums", "trainer.compute_secs").unwrap_or(0.0);
    let comm = f("sums", "trainer.comm_secs").unwrap_or(0.0);
    let stall = f("sums", "net.gather_stall_secs").unwrap_or(0.0);
    let busy_total = compute + comm + stall;
    let utilization = if busy_total > 0.0 { compute / busy_total } else { 0.0 };
    let workers: Vec<Value> = crate::obs::telemetry::fleet()
        .iter()
        .map(|(v, w)| {
            Value::obj(vec![
                ("worker", Value::Num(*v as f64)),
                ("round", Value::Num(w.round as f64)),
                (
                    "rtt_us",
                    if w.rtt_us > 0 { Value::Num(w.rtt_us as f64) } else { Value::Null },
                ),
                ("dropped_spans", Value::Num(w.dropped as f64)),
            ])
        })
        .collect();
    Value::obj(vec![
        ("epoch", Value::Num(f("counters", "trainer.epochs").unwrap_or(0.0))),
        (
            "err",
            f("gauges", "trainer.err").map(Value::Num).unwrap_or(Value::Null),
        ),
        ("utilization", Value::Num(utilization)),
        ("bytes_sent", Value::Num(f("counters", "net.bytes_sent").unwrap_or(0.0))),
        ("bytes_recv", Value::Num(f("counters", "net.bytes_recv").unwrap_or(0.0))),
        ("workers", Value::Arr(workers)),
    ])
}

/// Render one human-readable status line from a [`status_value`] tick.
fn status_line(v: &Value) -> String {
    let err = match v.get_f64("err") {
        Some(e) => format!("{e:.6e}"),
        None => "-".to_string(),
    };
    let workers = v
        .get("workers")
        .and_then(|w| w.as_arr())
        .map(|w| w.len())
        .unwrap_or(0);
    format!(
        "[watch] epoch={} err={} util={:.1}% bytes_sent={} bytes_recv={} workers={}",
        v.get_f64("epoch").unwrap_or(0.0),
        err,
        100.0 * v.get_f64("utilization").unwrap_or(0.0),
        v.get_f64("bytes_sent").unwrap_or(0.0),
        v.get_f64("bytes_recv").unwrap_or(0.0),
        workers,
    )
}

/// A running watch ticker; call [`Watch::stop`] to flush the final
/// tick and join the thread.
pub struct Watch {
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Start the ticker. Every `period` it prints a `[watch]` line to
/// stderr and, if `status_path` is set, appends one compact JSON
/// object per tick (JSONL). Never fails the run: file errors are
/// logged once at stop time via the return of the thread, not raised.
pub fn start(status_path: Option<PathBuf>, period: Duration) -> Watch {
    let stop = Arc::new(AtomicBool::new(false));
    let flag = stop.clone();
    let join = std::thread::Builder::new()
        .name("obs-watch".to_string())
        .spawn(move || {
            let mut file = status_path.as_ref().and_then(|p| {
                if let Some(dir) = p.parent() {
                    if !dir.as_os_str().is_empty() {
                        let _ = std::fs::create_dir_all(dir);
                    }
                }
                std::fs::OpenOptions::new().create(true).append(true).open(p).ok()
            });
            loop {
                // Sleep in short slices so stop() returns promptly.
                let mut slept = Duration::ZERO;
                while slept < period && !flag.load(Ordering::SeqCst) {
                    let slice = Duration::from_millis(25).min(period - slept);
                    std::thread::sleep(slice);
                    slept += slice;
                }
                let last = flag.load(Ordering::SeqCst);
                let tick = status_value();
                eprintln!("{}", status_line(&tick));
                if let Some(f) = file.as_mut() {
                    let _ = writeln!(f, "{}", crate::ser::to_string_compact(&tick));
                }
                if last {
                    return; // final tick emitted after stop was requested
                }
            }
        })
        .ok();
    Watch { stop, join }
}

impl Watch {
    /// Request the final tick, then join the ticker thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_value_reads_registry_and_fleet() {
        let _g = crate::obs::test_lock();
        crate::obs::enable();
        crate::obs::metrics::reset();
        crate::obs::telemetry::clear();
        crate::obs::metrics::add("trainer.epochs", 3);
        crate::obs::metrics::fset("trainer.err", 0.25);
        crate::obs::metrics::fadd("trainer.compute_secs", 3.0);
        crate::obs::metrics::fadd("trainer.comm_secs", 1.0);
        crate::obs::telemetry::record_link(0, 150, 2);
        crate::obs::disable();
        let v = status_value();
        assert_eq!(v.get_f64("epoch"), Some(3.0));
        assert_eq!(v.get_f64("err"), Some(0.25));
        assert_eq!(v.get_f64("utilization"), Some(0.75));
        let ws = v.get("workers").unwrap().as_arr().unwrap();
        assert_eq!(ws.len(), 1);
        assert_eq!(ws[0].get_f64("rtt_us"), Some(150.0));
        let line = status_line(&v);
        assert!(line.contains("epoch=3"));
        assert!(line.contains("util=75.0%"));
        crate::obs::metrics::reset();
        crate::obs::telemetry::clear();
    }

    #[test]
    fn ticker_appends_jsonl_and_stops() {
        let _g = crate::obs::test_lock();
        crate::obs::metrics::reset();
        let dir = std::env::temp_dir().join(format!("anytime-watch-{}", std::process::id()));
        let path = dir.join("status.jsonl");
        let _ = std::fs::remove_file(&path);
        let w = start(Some(path.clone()), Duration::from_millis(10));
        std::thread::sleep(Duration::from_millis(40));
        w.stop();
        let text = std::fs::read_to_string(&path).expect("status.jsonl written");
        let lines: Vec<&str> = text.lines().collect();
        assert!(!lines.is_empty());
        for line in lines {
            let v = crate::ser::parse(line).expect("each tick is one JSON object");
            assert!(v.get("epoch").is_some());
            assert!(v.get("workers").is_some());
        }
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }
}
