//! Post-run accounting: the paper's time ledger as a report.
//!
//! [`RunReport`] folds the trainer's per-epoch records
//! ([`EpochStats`], plus [`NetEpochStats`] when a networked runtime
//! ran) into the accounting the paper argues in: each epoch is a
//! fixed compute window `T`, a worker is *busy* until its finishing
//! time and *gather-stalled* for the rest of the window, the gap
//! between the slowest and second-slowest finisher is charged to the
//! slowest as *straggler* time, and utilization is busy time over
//! total window time. The report renders as a terminal table
//! (`train --report`), serializes with stable keys
//! (`<out>/report.json`, next to the figures), and rolls up across
//! sweep cells ([`render_sweep`]).
//!
//! This pillar is a pure data transform — it reads only what the run
//! already recorded, so it needs no instrumentation, is not gated on
//! [`crate::obs::enabled`], and trivially preserves bit-exactness.

use crate::coordinator::runtime::NetEpochStats;
use crate::coordinator::EpochStats;
use crate::ser::Value;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// One worker's row of the ledger.
#[derive(Clone, Debug)]
pub struct WorkerLine {
    /// Seconds spent computing + uplinking (finishing times clamped to
    /// each epoch's window).
    pub busy_secs: f64,
    /// Seconds idle inside epoch windows after finishing (or the whole
    /// window, for epochs it never reported in).
    pub stall_secs: f64,
    /// Seconds this worker was *the* straggler: its margin over the
    /// second-slowest finisher, summed over epochs it finished last.
    pub straggler_secs: f64,
    /// `busy_secs` over the run's total window time, in `[0, 1]`.
    pub utilization: f64,
    /// Gradient steps contributed across the run (Σ q_v).
    pub steps: usize,
    /// Epochs with no report from this worker (dead, or past `T_c`).
    pub missed_epochs: usize,
    /// Mean task→report round-trip seconds (dist runtime only).
    pub mean_rtt_secs: Option<f64>,
}

/// The whole run's ledger (module docs for the accounting rules).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub epochs: usize,
    pub workers: Vec<WorkerLine>,
    /// Σ per-epoch compute window (the paper's `T` per epoch).
    pub compute_secs: f64,
    /// Σ per-epoch communication charge.
    pub comm_secs: f64,
    /// Σ worker stall (fleet-seconds idle inside compute windows).
    pub gather_stall_secs: f64,
    /// Fleet utilization: mean of per-worker utilizations.
    pub utilization: f64,
    /// Wire bytes (0 for in-process runtimes).
    pub bytes_sent: u64,
    pub bytes_recv: u64,
    /// Real `T_c` deadline misses on the dist wire.
    pub dropped_reports: usize,
    /// `(bytes_sent + bytes_recv) / epochs`.
    pub bytes_per_epoch: f64,
    /// Fleet link RTT envelope from the continuous heartbeat-echo
    /// estimator (dist only): min of per-epoch minima / mean of means /
    /// max of maxima. `None` when no link ever produced an estimate.
    pub link_rtt_min_secs: Option<f64>,
    pub link_rtt_mean_secs: Option<f64>,
    pub link_rtt_max_secs: Option<f64>,
}

impl RunReport {
    /// Aggregate a run's epoch records. `net` may be empty (in-process
    /// runtimes) or one record per epoch (dist).
    pub fn from_run(epochs: &[EpochStats], net: &[NetEpochStats]) -> RunReport {
        let n = epochs.first().map(|e| e.q.len()).unwrap_or(0);
        let mut busy = vec![0.0f64; n];
        let mut stall = vec![0.0f64; n];
        let mut straggler = vec![0.0f64; n];
        let mut steps = vec![0usize; n];
        let mut missed = vec![0usize; n];
        let mut total_window = 0.0f64;
        let mut compute_secs = 0.0f64;
        let mut comm_secs = 0.0f64;

        for ep in epochs {
            let window = ep.compute_secs.max(0.0);
            total_window += window;
            compute_secs += ep.compute_secs;
            comm_secs += ep.comm_secs;
            // Clamped finishing times, for busy/stall and the
            // straggler margin.
            let mut finishes: Vec<(usize, f64)> = Vec::with_capacity(n);
            for v in 0..n {
                steps[v] += ep.q.get(v).copied().unwrap_or(0);
                match ep.worker_finish.get(v).copied().flatten() {
                    Some(f) => {
                        let b = f.clamp(0.0, window);
                        busy[v] += b;
                        stall[v] += window - b;
                        finishes.push((v, b));
                    }
                    None => {
                        missed[v] += 1;
                        stall[v] += window;
                    }
                }
            }
            // Straggler attribution: the slowest finisher is charged
            // its margin over the runner-up.
            if finishes.len() >= 2 {
                finishes.sort_by(|a, b| a.1.total_cmp(&b.1));
                let (slowest, t_last) = finishes[finishes.len() - 1];
                let t_second = finishes[finishes.len() - 2].1;
                straggler[slowest] += (t_last - t_second).max(0.0);
            }
        }

        // Per-worker RTT means over the epochs that have one.
        let mut rtt_sum = vec![0.0f64; n];
        let mut rtt_cnt = vec![0usize; n];
        let mut bytes_sent = 0u64;
        let mut bytes_recv = 0u64;
        let mut dropped_reports = 0usize;
        let mut hb_min = f64::INFINITY;
        let mut hb_max = f64::NEG_INFINITY;
        let mut hb_mean_sum = 0.0f64;
        let mut hb_mean_cnt = 0usize;
        for ne in net {
            bytes_sent += ne.bytes_sent;
            bytes_recv += ne.bytes_recv;
            dropped_reports += ne.dropped_reports;
            for v in 0..n {
                if let Some(r) = ne.rtt_secs.get(v).copied().flatten() {
                    rtt_sum[v] += r;
                    rtt_cnt[v] += 1;
                }
            }
            if let Some(m) = ne.hb_rtt_min_secs {
                hb_min = hb_min.min(m);
            }
            if let Some(m) = ne.hb_rtt_max_secs {
                hb_max = hb_max.max(m);
            }
            if let Some(m) = ne.hb_rtt_mean_secs {
                hb_mean_sum += m;
                hb_mean_cnt += 1;
            }
        }

        let workers: Vec<WorkerLine> = (0..n)
            .map(|v| WorkerLine {
                busy_secs: busy[v],
                stall_secs: stall[v],
                straggler_secs: straggler[v],
                utilization: if total_window > 0.0 { busy[v] / total_window } else { 0.0 },
                steps: steps[v],
                missed_epochs: missed[v],
                mean_rtt_secs: (rtt_cnt[v] > 0).then(|| rtt_sum[v] / rtt_cnt[v] as f64),
            })
            .collect();
        let utilization = if n > 0 {
            workers.iter().map(|w| w.utilization).sum::<f64>() / n as f64
        } else {
            0.0
        };
        RunReport {
            epochs: epochs.len(),
            gather_stall_secs: workers.iter().map(|w| w.stall_secs).sum(),
            utilization,
            workers,
            compute_secs,
            comm_secs,
            bytes_sent,
            bytes_recv,
            dropped_reports,
            bytes_per_epoch: if epochs.is_empty() {
                0.0
            } else {
                (bytes_sent + bytes_recv) as f64 / epochs.len() as f64
            },
            link_rtt_min_secs: hb_min.is_finite().then_some(hb_min),
            link_rtt_mean_secs: (hb_mean_cnt > 0).then(|| hb_mean_sum / hb_mean_cnt as f64),
            link_rtt_max_secs: hb_max.is_finite().then_some(hb_max),
        }
    }

    /// The terminal table `train --report` prints.
    pub fn render_table(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "== run report: {} epochs, {} workers ==", self.epochs, self.workers.len());
        let _ = writeln!(
            s,
            "time      compute {:.3}s · comm {:.3}s · gather-stall {:.3}s · utilization {:.1}%",
            self.compute_secs,
            self.comm_secs,
            self.gather_stall_secs,
            self.utilization * 100.0
        );
        if self.bytes_sent + self.bytes_recv > 0 {
            let _ = writeln!(
                s,
                "wire      sent {} B · recv {} B · {:.0} B/epoch · dropped reports {}",
                self.bytes_sent, self.bytes_recv, self.bytes_per_epoch, self.dropped_reports
            );
        }
        if let (Some(lo), Some(mean), Some(hi)) =
            (self.link_rtt_min_secs, self.link_rtt_mean_secs, self.link_rtt_max_secs)
        {
            let _ = writeln!(
                s,
                "link rtt  min {:.2} ms · mean {:.2} ms · max {:.2} ms (heartbeat echo)",
                lo * 1e3,
                mean * 1e3,
                hi * 1e3
            );
        }
        let _ = writeln!(
            s,
            "{:<5} {:>10} {:>10} {:>12} {:>7} {:>8} {:>7} {:>9}",
            "", "busy_s", "stall_s", "straggler_s", "util%", "steps", "missed", "rtt_ms"
        );
        for (v, w) in self.workers.iter().enumerate() {
            let rtt = match w.mean_rtt_secs {
                Some(r) => format!("{:.2}", r * 1e3),
                None => "-".to_string(),
            };
            let _ = writeln!(
                s,
                "W{v:<4} {:>10.3} {:>10.3} {:>12.3} {:>7.1} {:>8} {:>7} {:>9}",
                w.busy_secs,
                w.stall_secs,
                w.straggler_secs,
                w.utilization * 100.0,
                w.steps,
                w.missed_epochs,
                rtt
            );
        }
        s
    }

    /// Stable-key JSON shape (what [`RunReport::write`] persists).
    pub fn to_json(&self) -> Value {
        let workers: Vec<Value> = self
            .workers
            .iter()
            .map(|w| {
                Value::obj(vec![
                    ("busy_secs", Value::Num(w.busy_secs)),
                    ("stall_secs", Value::Num(w.stall_secs)),
                    ("straggler_secs", Value::Num(w.straggler_secs)),
                    ("utilization", Value::Num(w.utilization)),
                    ("steps", w.steps.into()),
                    ("missed_epochs", w.missed_epochs.into()),
                    (
                        "mean_rtt_secs",
                        w.mean_rtt_secs.map(Value::Num).unwrap_or(Value::Null),
                    ),
                ])
            })
            .collect();
        Value::obj(vec![
            ("epochs", self.epochs.into()),
            ("compute_secs", Value::Num(self.compute_secs)),
            ("comm_secs", Value::Num(self.comm_secs)),
            ("gather_stall_secs", Value::Num(self.gather_stall_secs)),
            ("utilization", Value::Num(self.utilization)),
            ("bytes_sent", Value::Num(self.bytes_sent as f64)),
            ("bytes_recv", Value::Num(self.bytes_recv as f64)),
            ("dropped_reports", self.dropped_reports.into()),
            ("bytes_per_epoch", Value::Num(self.bytes_per_epoch)),
            (
                "link_rtt_min_secs",
                self.link_rtt_min_secs.map(Value::Num).unwrap_or(Value::Null),
            ),
            (
                "link_rtt_mean_secs",
                self.link_rtt_mean_secs.map(Value::Num).unwrap_or(Value::Null),
            ),
            (
                "link_rtt_max_secs",
                self.link_rtt_max_secs.map(Value::Num).unwrap_or(Value::Null),
            ),
            ("workers", Value::Arr(workers)),
        ])
    }

    /// Write `report.json` into `dir` (next to the figures); returns
    /// the path written.
    pub fn write(&self, dir: &Path) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join("report.json");
        std::fs::write(&path, crate::ser::to_string_pretty(&self.to_json()))?;
        Ok(path)
    }

    /// Every aggregate and per-worker number is finite (the obs-smoke
    /// CI gate).
    pub fn is_finite(&self) -> bool {
        [self.compute_secs, self.comm_secs, self.gather_stall_secs, self.utilization, self.bytes_per_epoch]
            .iter()
            .all(|x| x.is_finite())
            && self.workers.iter().all(|w| {
                [w.busy_secs, w.stall_secs, w.straggler_secs, w.utilization]
                    .iter()
                    .all(|x| x.is_finite())
                    && w.mean_rtt_secs.map(f64::is_finite).unwrap_or(true)
            })
            && [self.link_rtt_min_secs, self.link_rtt_mean_secs, self.link_rtt_max_secs]
                .iter()
                .all(|x| x.map(f64::is_finite).unwrap_or(true))
    }
}

/// Sweep-level roll-up: one line per cell (`sweep --report`).
pub fn render_sweep(rows: &[(&str, &RunReport)]) -> String {
    let name_w = rows.iter().map(|(n, _)| n.len()).max().unwrap_or(4).max(4);
    let mut s = String::new();
    let _ = writeln!(s, "== sweep report: {} cells ==", rows.len());
    let _ = writeln!(
        s,
        "{:<name_w$} {:>7} {:>11} {:>10} {:>13} {:>7}",
        "cell", "epochs", "compute_s", "comm_s", "gather_stall", "util%"
    );
    for (name, r) in rows {
        let _ = writeln!(
            s,
            "{name:<name_w$} {:>7} {:>11.3} {:>10.3} {:>13.3} {:>7.1}",
            r.epochs,
            r.compute_secs,
            r.comm_secs,
            r.gather_stall_secs,
            r.utilization * 100.0
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ep(window: f64, finish: Vec<Option<f64>>, q: Vec<usize>) -> EpochStats {
        let n = q.len();
        EpochStats {
            q,
            received: vec![true; n],
            compute_secs: window,
            comm_secs: 0.5,
            lambda: vec![1.0 / n as f64; n],
            worker_finish: finish,
        }
    }

    #[test]
    fn ledger_accounting() {
        // Two epochs of window 10; W1 is the straggler both times, W2
        // misses the second epoch entirely.
        let epochs = vec![
            ep(10.0, vec![Some(4.0), Some(9.0), Some(6.0)], vec![40, 90, 60]),
            ep(10.0, vec![Some(5.0), Some(8.0), None], vec![50, 80, 0]),
        ];
        let r = RunReport::from_run(&epochs, &[]);
        assert_eq!(r.epochs, 2);
        assert_eq!(r.workers.len(), 3);
        assert!((r.compute_secs - 20.0).abs() < 1e-12);
        assert!((r.comm_secs - 1.0).abs() < 1e-12);
        // W0: busy 9, stall 11. W1: busy 17, stall 3. W2: busy 6, stall 14.
        assert!((r.workers[0].busy_secs - 9.0).abs() < 1e-12);
        assert!((r.workers[1].busy_secs - 17.0).abs() < 1e-12);
        assert!((r.workers[2].busy_secs - 6.0).abs() < 1e-12);
        assert!((r.gather_stall_secs - 28.0).abs() < 1e-12);
        // Straggler margins: epoch 1 → W1 by 9−6=3; epoch 2 → W1 by 8−5=3.
        assert!((r.workers[1].straggler_secs - 6.0).abs() < 1e-12);
        assert_eq!(r.workers[0].straggler_secs, 0.0);
        assert_eq!(r.workers[2].missed_epochs, 1);
        assert_eq!(r.workers[1].steps, 170);
        assert!((r.workers[1].utilization - 17.0 / 20.0).abs() < 1e-12);
        assert!(r.utilization > 0.0 && r.utilization < 1.0);
        assert!(r.is_finite());
        assert_eq!(r.bytes_sent, 0);
    }

    #[test]
    fn finish_times_clamp_to_window() {
        // A finishing time past the window (uplink landed after T)
        // can't make busy > window or stall negative.
        let epochs = vec![ep(10.0, vec![Some(12.0), Some(2.0)], vec![120, 20])];
        let r = RunReport::from_run(&epochs, &[]);
        assert!((r.workers[0].busy_secs - 10.0).abs() < 1e-12);
        assert_eq!(r.workers[0].stall_secs, 0.0);
        assert!((r.workers[0].utilization - 1.0).abs() < 1e-12);
    }

    #[test]
    fn net_epochs_fold_in() {
        let epochs = vec![ep(10.0, vec![Some(1.0), Some(2.0)], vec![10, 20])];
        let net = vec![NetEpochStats {
            bytes_sent: 1000,
            bytes_recv: 400,
            rtt_secs: vec![Some(0.02), None],
            dropped_reports: 1,
            hb_rtt_min_secs: Some(0.001),
            hb_rtt_mean_secs: Some(0.002),
            hb_rtt_max_secs: Some(0.004),
        }];
        let r = RunReport::from_run(&epochs, &net);
        assert_eq!(r.bytes_sent, 1000);
        assert_eq!(r.bytes_recv, 400);
        assert_eq!(r.dropped_reports, 1);
        assert!((r.bytes_per_epoch - 1400.0).abs() < 1e-12);
        assert_eq!(r.workers[0].mean_rtt_secs, Some(0.02));
        assert_eq!(r.workers[1].mean_rtt_secs, None);
        assert_eq!(r.link_rtt_min_secs, Some(0.001));
        assert_eq!(r.link_rtt_mean_secs, Some(0.002));
        assert_eq!(r.link_rtt_max_secs, Some(0.004));
        assert!(r.is_finite());
        let table = r.render_table();
        assert!(table.contains("utilization"));
        assert!(table.contains("gather-stall"));
        assert!(table.contains("link rtt"));
        assert!(table.contains("W0"));
        let json = r.to_json();
        assert_eq!(json.get_usize("epochs"), Some(1));
        assert_eq!(json.get_f64("link_rtt_max_secs"), Some(0.004));
        assert_eq!(json.get("workers").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn runs_without_link_estimates_report_null_rtt_envelope() {
        let epochs = vec![ep(10.0, vec![Some(1.0), Some(2.0)], vec![10, 20])];
        let net = vec![NetEpochStats {
            bytes_sent: 10,
            rtt_secs: vec![None, None],
            ..NetEpochStats::default()
        }];
        let r = RunReport::from_run(&epochs, &net);
        assert_eq!(r.link_rtt_min_secs, None);
        assert_eq!(r.link_rtt_mean_secs, None);
        assert_eq!(r.link_rtt_max_secs, None);
        assert!(r.is_finite());
        assert!(!r.render_table().contains("link rtt"));
        assert_eq!(r.to_json().get("link_rtt_min_secs"), Some(&Value::Null));
    }

    #[test]
    fn empty_run_is_well_defined() {
        let r = RunReport::from_run(&[], &[]);
        assert_eq!(r.epochs, 0);
        assert!(r.workers.is_empty());
        assert_eq!(r.utilization, 0.0);
        assert!(r.is_finite());
    }

    #[test]
    fn sweep_rollup_lists_cells() {
        let epochs = vec![ep(10.0, vec![Some(1.0), Some(2.0)], vec![10, 20])];
        let r = RunReport::from_run(&epochs, &[]);
        let s = render_sweep(&[("cell-a", &r), ("cell-b-long-name", &r)]);
        assert!(s.contains("2 cells"));
        assert!(s.contains("cell-b-long-name"));
    }
}
