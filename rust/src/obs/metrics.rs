//! Process-wide atomic metrics behind a name-keyed registry.
//!
//! Four instrument kinds, all lock-free to update once registered:
//!
//! * **counter** — monotonically increasing `u64` ([`add`]): bytes on
//!   the wire, dropped reports, epochs run.
//! * **gauge** — last-write-wins `u64` ([`gauge`]) or `f64` ([`fset`]):
//!   current round, live worker count, latest eval error, per-link RTT.
//! * **sum** — accumulating `f64` ([`fadd`], CAS on the bit pattern):
//!   gather-stall seconds, per-worker busy seconds.
//! * **histogram** — count/sum/min/max plus log2-bucketed counts
//!   ([`observe`]): per-dispatch step counts `q`.
//!
//! Updates early-return while [`crate::obs::enabled`] is false (one
//! relaxed load, no allocation or locking), so the disabled cost at a
//! call site is negligible. [`snapshot`] freezes everything into a
//! stable-key [`Value`] — `BTreeMap` ordering means two snapshots of
//! identical state serialize identically, which is what the
//! determinism test in `rust/tests/obs_integration.rs` pins.
//!
//! Names are flat dotted strings (`net.bytes_sent`,
//! `worker.3.busy_secs` — taxonomy in DESIGN.md §8). The first
//! registration of a name fixes its kind; a later call of a different
//! kind on the same name is a silent no-op rather than a panic
//! (observability must never take down a run).

use crate::ser::Value;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Log2 bucket count: bucket 0 is `x <= 1`, bucket `k >= 1` holds
/// samples with `floor(log2 x) == k - 1` (so `[2^(k-1), 2^k)`, modulo
/// the bucket-0 edge), and the last bucket absorbs the tail.
const BUCKETS: usize = 16;

struct HistCell {
    count: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl HistCell {
    fn new() -> HistCell {
        HistCell {
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

fn bucket_of(x: f64) -> usize {
    if !(x > 1.0) {
        return 0;
    }
    ((x.log2().floor() as usize) + 1).min(BUCKETS - 1)
}

enum Metric {
    Counter(AtomicU64),
    Gauge(AtomicU64),
    FGauge(AtomicU64),
    FSum(AtomicU64),
    Hist(HistCell),
}

fn registry() -> &'static Mutex<BTreeMap<String, Arc<Metric>>> {
    static REGISTRY: OnceLock<Mutex<BTreeMap<String, Arc<Metric>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Get-or-insert: the registry lock is held only for the map lookup;
/// the returned `Arc` is updated without any lock.
fn metric(name: &str, make: fn() -> Metric) -> Arc<Metric> {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    match reg.get(name) {
        Some(m) => m.clone(),
        None => {
            let m = Arc::new(make());
            reg.insert(name.to_string(), m.clone());
            m
        }
    }
}

/// Atomically `*cell += x` on an f64 stored as bits.
fn fadd_bits(cell: &AtomicU64, x: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + x).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

fn fmin_bits(cell: &AtomicU64, x: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    while x < f64::from_bits(cur) {
        match cell.compare_exchange_weak(cur, x.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

fn fmax_bits(cell: &AtomicU64, x: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    while x > f64::from_bits(cur) {
        match cell.compare_exchange_weak(cur, x.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Increment counter `name` by `n`.
pub fn add(name: &str, n: u64) {
    if !crate::obs::enabled() {
        return;
    }
    if let Metric::Counter(c) = &*metric(name, || Metric::Counter(AtomicU64::new(0))) {
        c.fetch_add(n, Ordering::Relaxed);
    }
}

/// Set gauge `name` to `x` (last write wins).
pub fn gauge(name: &str, x: u64) {
    if !crate::obs::enabled() {
        return;
    }
    if let Metric::Gauge(g) = &*metric(name, || Metric::Gauge(AtomicU64::new(0))) {
        g.store(x, Ordering::Relaxed);
    }
}

/// Set float gauge `name` to `x` (last write wins; bits stored in an
/// `AtomicU64`). Lands in the same `"gauges"` snapshot section as
/// [`gauge`] — the kinds differ only in what the writer hands us.
pub fn fset(name: &str, x: f64) {
    if !crate::obs::enabled() {
        return;
    }
    if let Metric::FGauge(g) = &*metric(name, || Metric::FGauge(AtomicU64::new(0f64.to_bits()))) {
        g.store(x.to_bits(), Ordering::Relaxed);
    }
}

/// Accumulate `x` into f64 sum `name`.
pub fn fadd(name: &str, x: f64) {
    if !crate::obs::enabled() {
        return;
    }
    if let Metric::FSum(s) = &*metric(name, || Metric::FSum(AtomicU64::new(0f64.to_bits()))) {
        fadd_bits(s, x);
    }
}

/// Record one sample into histogram `name`.
pub fn observe(name: &str, x: f64) {
    if !crate::obs::enabled() {
        return;
    }
    if let Metric::Hist(h) = &*metric(name, || Metric::Hist(HistCell::new())) {
        h.count.fetch_add(1, Ordering::Relaxed);
        fadd_bits(&h.sum_bits, x);
        fmin_bits(&h.min_bits, x);
        fmax_bits(&h.max_bits, x);
        h.buckets[bucket_of(x)].fetch_add(1, Ordering::Relaxed);
    }
}

/// Freeze every registered metric into a stable-key JSON value:
/// `{"counters": {...}, "gauges": {...}, "sums": {...}, "hists": {...}}`.
/// Works whether or not collection is currently enabled.
pub fn snapshot() -> Value {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    let mut counters = BTreeMap::new();
    let mut gauges = BTreeMap::new();
    let mut sums = BTreeMap::new();
    let mut hists = BTreeMap::new();
    for (name, m) in reg.iter() {
        match &**m {
            Metric::Counter(c) => {
                counters.insert(name.clone(), Value::Num(c.load(Ordering::Relaxed) as f64));
            }
            Metric::Gauge(g) => {
                gauges.insert(name.clone(), Value::Num(g.load(Ordering::Relaxed) as f64));
            }
            Metric::FGauge(g) => {
                gauges.insert(
                    name.clone(),
                    Value::Num(f64::from_bits(g.load(Ordering::Relaxed))),
                );
            }
            Metric::FSum(s) => {
                sums.insert(
                    name.clone(),
                    Value::Num(f64::from_bits(s.load(Ordering::Relaxed))),
                );
            }
            Metric::Hist(h) => {
                let count = h.count.load(Ordering::Relaxed);
                let minmax = |bits: &AtomicU64| {
                    if count == 0 {
                        Value::Null
                    } else {
                        Value::Num(f64::from_bits(bits.load(Ordering::Relaxed)))
                    }
                };
                let buckets: Vec<Value> = h
                    .buckets
                    .iter()
                    .map(|b| Value::Num(b.load(Ordering::Relaxed) as f64))
                    .collect();
                hists.insert(
                    name.clone(),
                    Value::obj(vec![
                        ("count", Value::Num(count as f64)),
                        ("sum", Value::Num(f64::from_bits(h.sum_bits.load(Ordering::Relaxed)))),
                        ("min", minmax(&h.min_bits)),
                        ("max", minmax(&h.max_bits)),
                        ("buckets", Value::Arr(buckets)),
                    ]),
                );
            }
        }
    }
    Value::obj(vec![
        ("counters", Value::Obj(counters)),
        ("gauges", Value::Obj(gauges)),
        ("sums", Value::Obj(sums)),
        ("hists", Value::Obj(hists)),
    ])
}

/// Drop every registered metric (tests / between sweep cells).
pub fn reset() {
    registry().lock().unwrap_or_else(|e| e.into_inner()).clear();
}

/// Write [`snapshot`] to `path` as pretty JSON (creates parent dirs).
pub fn write_json(path: &Path) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, crate::ser::to_string_pretty(&snapshot()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_updates_register_nothing() {
        let _g = crate::obs::test_lock();
        crate::obs::disable();
        reset();
        add("t.counter", 3);
        fadd("t.sum", 1.5);
        observe("t.hist", 2.0);
        let snap = snapshot();
        assert!(snap.get("counters").unwrap().as_obj().unwrap().is_empty());
        assert!(snap.get("hists").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn instruments_accumulate_and_snapshot() {
        let _g = crate::obs::test_lock();
        crate::obs::enable();
        reset();
        add("t.counter", 2);
        add("t.counter", 3);
        gauge("t.gauge", 7);
        gauge("t.gauge", 9);
        fset("t.fgauge", 0.5);
        fset("t.fgauge", 0.125);
        fadd("t.sum", 0.25);
        fadd("t.sum", 0.5);
        observe("t.hist", 0.5);
        observe("t.hist", 3.0);
        observe("t.hist", 1e12); // tail bucket
        crate::obs::disable();
        let snap = snapshot();
        assert_eq!(snap.get("counters").unwrap().get_f64("t.counter"), Some(5.0));
        assert_eq!(snap.get("gauges").unwrap().get_f64("t.gauge"), Some(9.0));
        assert_eq!(snap.get("gauges").unwrap().get_f64("t.fgauge"), Some(0.125));
        assert_eq!(snap.get("sums").unwrap().get_f64("t.sum"), Some(0.75));
        let h = snap.get("hists").unwrap().get("t.hist").unwrap();
        assert_eq!(h.get_f64("count"), Some(3.0));
        assert_eq!(h.get_f64("min"), Some(0.5));
        assert_eq!(h.get_f64("max"), Some(1e12));
        let buckets = h.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), BUCKETS);
        assert_eq!(buckets[0].as_f64(), Some(1.0)); // 0.5
        assert_eq!(buckets[2].as_f64(), Some(1.0)); // 3.0 ∈ [2, 4)
        assert_eq!(buckets[BUCKETS - 1].as_f64(), Some(1.0)); // 1e12 tail
        reset();
    }

    #[test]
    fn kind_mismatch_is_a_noop() {
        let _g = crate::obs::test_lock();
        crate::obs::enable();
        reset();
        add("t.kind", 1);
        fadd("t.kind", 9.0); // wrong kind: silently ignored
        crate::obs::disable();
        let snap = snapshot();
        assert_eq!(snap.get("counters").unwrap().get_f64("t.kind"), Some(1.0));
        assert!(snap.get("sums").unwrap().as_obj().unwrap().is_empty());
        reset();
    }

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket_of(-3.0), 0);
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(1.0), 0);
        assert_eq!(bucket_of(1.5), 1); // (1, 2)
        assert_eq!(bucket_of(2.5), 2); // [2, 4)
        assert_eq!(bucket_of(f64::NAN), 0);
        assert_eq!(bucket_of(f64::INFINITY), BUCKETS - 1);
    }
}
