//! Prometheus text-exposition rendering and a std-only `/metrics`
//! endpoint (DESIGN.md §8).
//!
//! [`render`] flattens the process-wide [`crate::obs::metrics`]
//! snapshot plus the per-worker fleet store
//! ([`crate::obs::telemetry`]) into Prometheus text format 0.0.4:
//! counters stay counters, gauges/sums become gauges, histograms are
//! summarized as `_count`/`_sum`/`_min`/`_max`, and fleet values get a
//! `{worker="v"}` label. Metric names are sanitized to
//! `[a-zA-Z0-9_:]` and prefixed `anytime_sgd_`.
//!
//! [`MetricsServer::serve`] binds a `TcpListener` (port 0 picks an
//! ephemeral port; the bound port is reported back) and answers every
//! HTTP request on a detached thread with the current [`render`]
//! output — enough for `curl` and a Prometheus scraper, no HTTP
//! library required. The server only ever *reads* observability
//! state on wall-clock cadence, so running it cannot perturb the
//! obs-on ≡ obs-off bit-exactness pin.

use crate::ser::Value;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Sanitize a dotted metric name into a Prometheus identifier:
/// `[a-zA-Z0-9_:]` survive, everything else becomes `_`.
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// Format an f64 the way the exposition format spells specials.
fn num(x: f64) -> String {
    if x.is_nan() {
        "NaN".to_string()
    } else if x == f64::INFINITY {
        "+Inf".to_string()
    } else if x == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{x}")
    }
}

fn push_family(out: &mut String, name: &str, kind: &str, value: f64) {
    out.push_str(&format!("# TYPE {name} {kind}\n{name} {}\n", num(value)));
}

/// Render the current metrics snapshot + fleet telemetry as
/// Prometheus text exposition format.
pub fn render() -> String {
    let snap = crate::obs::metrics::snapshot();
    let mut out = String::new();
    let section = |v: &Value, key: &str| -> Vec<(String, f64)> {
        v.get(key)
            .and_then(|s| s.as_obj().cloned())
            .map(|m| {
                m.iter()
                    .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
                    .collect()
            })
            .unwrap_or_default()
    };
    for (name, x) in section(&snap, "counters") {
        push_family(&mut out, &format!("anytime_sgd_{}", sanitize(&name)), "counter", x);
    }
    for (name, x) in section(&snap, "gauges") {
        push_family(&mut out, &format!("anytime_sgd_{}", sanitize(&name)), "gauge", x);
    }
    for (name, x) in section(&snap, "sums") {
        push_family(&mut out, &format!("anytime_sgd_{}", sanitize(&name)), "gauge", x);
    }
    if let Some(hists) = snap.get("hists").and_then(|h| h.as_obj()) {
        for (name, h) in hists {
            let base = format!("anytime_sgd_{}", sanitize(name));
            for field in ["count", "sum", "min", "max"] {
                if let Some(x) = h.get_f64(field) {
                    push_family(&mut out, &format!("{base}_{field}"), "gauge", x);
                }
            }
        }
    }
    let fleet = crate::obs::telemetry::fleet();
    if !fleet.is_empty() {
        out.push_str("# TYPE anytime_sgd_worker_link_rtt_seconds gauge\n");
        for (v, w) in &fleet {
            if w.rtt_us > 0 {
                out.push_str(&format!(
                    "anytime_sgd_worker_link_rtt_seconds{{worker=\"{v}\"}} {}\n",
                    num(w.rtt_us as f64 * 1e-6)
                ));
            }
        }
        out.push_str("# TYPE anytime_sgd_worker_dropped_spans gauge\n");
        for (v, w) in &fleet {
            out.push_str(&format!(
                "anytime_sgd_worker_dropped_spans{{worker=\"{v}\"}} {}\n",
                w.dropped
            ));
        }
        out.push_str("# TYPE anytime_sgd_worker_round gauge\n");
        for (v, w) in &fleet {
            out.push_str(&format!("anytime_sgd_worker_round{{worker=\"{v}\"}} {}\n", w.round));
        }
        // Each worker's own metrics snapshot, labeled by worker index.
        let mut names: Vec<&String> =
            fleet.values().flat_map(|w| w.metrics.keys()).collect();
        names.sort();
        names.dedup();
        for name in names {
            out.push_str(&format!("# TYPE anytime_sgd_fleet_{} gauge\n", sanitize(name)));
            for (v, w) in &fleet {
                if let Some(x) = w.metrics.get(name) {
                    out.push_str(&format!(
                        "anytime_sgd_fleet_{}{{worker=\"{v}\"}} {}\n",
                        sanitize(name),
                        num(*x)
                    ));
                }
            }
        }
    }
    out
}

/// A running `/metrics` endpoint; dropping the handle leaves the
/// detached thread serving until [`MetricsServer::shutdown`] or
/// process exit.
pub struct MetricsServer {
    port: u16,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `127.0.0.1:port` (0 ⇒ ephemeral) and serve [`render`] to
    /// every request on a background thread. Returns the server
    /// handle; the actual bound port is [`MetricsServer::port`].
    pub fn serve(port: u16) -> std::io::Result<MetricsServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let port = listener.local_addr()?.port();
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let join = std::thread::Builder::new()
            .name("obs-metrics-http".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if flag.load(Ordering::SeqCst) {
                        break;
                    }
                    if let Ok(stream) = conn {
                        // Serve inline: responses are tiny and the
                        // endpoint is a debugging surface, not a
                        // production load balancer.
                        let _ = answer(stream);
                    }
                }
            })?;
        Ok(MetricsServer { port, stop, join: Some(join) })
    }

    /// The bound TCP port (useful with `serve(0)`).
    pub fn port(&self) -> u16 {
        self.port
    }

    /// Stop accepting and join the serving thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(("127.0.0.1", self.port));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Read (and discard) the request, write one HTTP/1.0 response with
/// the current exposition body, close.
fn answer(mut stream: TcpStream) -> std::io::Result<()> {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    let mut buf = [0u8; 1024];
    let _ = stream.read(&mut buf); // best-effort; any request gets /metrics
    let body = render();
    let head = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_keeps_prometheus_charset() {
        assert_eq!(sanitize("net.bytes_sent"), "net_bytes_sent");
        assert_eq!(sanitize("worker.3.busy secs"), "worker_3_busy_secs");
        assert_eq!(sanitize("a:b_9"), "a:b_9");
    }

    #[test]
    fn render_emits_typed_families_and_fleet_labels() {
        let _g = crate::obs::test_lock();
        crate::obs::enable();
        crate::obs::metrics::reset();
        crate::obs::telemetry::clear();
        crate::obs::metrics::add("net.bytes_sent", 42);
        crate::obs::metrics::fset("trainer.err", 0.5);
        crate::obs::metrics::observe("dispatch.q", 3.0);
        crate::obs::telemetry::record_link(1, 250, 10);
        crate::obs::telemetry::record_worker(1, 4, 2, &[("worker.busy_secs".into(), 1.5)]);
        crate::obs::disable();
        let text = render();
        assert!(text.contains("# TYPE anytime_sgd_net_bytes_sent counter\n"));
        assert!(text.contains("anytime_sgd_net_bytes_sent 42\n"));
        assert!(text.contains("# TYPE anytime_sgd_trainer_err gauge\n"));
        assert!(text.contains("anytime_sgd_trainer_err 0.5\n"));
        assert!(text.contains("anytime_sgd_dispatch_q_count 1\n"));
        assert!(text.contains("anytime_sgd_worker_link_rtt_seconds{worker=\"1\"} 0.00025\n"));
        assert!(text.contains("anytime_sgd_worker_dropped_spans{worker=\"1\"} 2\n"));
        assert!(text.contains("anytime_sgd_fleet_worker_busy_secs{worker=\"1\"} 1.5\n"));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (name, val) = line.rsplit_once(' ').expect("name value");
            assert!(!name.is_empty());
            assert!(
                val.parse::<f64>().is_ok() || ["NaN", "+Inf", "-Inf"].contains(&val),
                "bad sample value {val:?}"
            );
        }
        crate::obs::metrics::reset();
        crate::obs::telemetry::clear();
    }

    #[test]
    fn server_answers_http_with_exposition_body() {
        let _g = crate::obs::test_lock();
        crate::obs::enable();
        crate::obs::metrics::reset();
        crate::obs::metrics::add("net.bytes_sent", 7);
        crate::obs::disable();
        let server = MetricsServer::serve(0).expect("bind loopback");
        let mut stream = TcpStream::connect(("127.0.0.1", server.port())).expect("connect");
        stream
            .write_all(b"GET /metrics HTTP/1.0\r\n\r\n")
            .expect("send request");
        let mut resp = String::new();
        stream.read_to_string(&mut resp).expect("read response");
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"));
        assert!(resp.contains("text/plain; version=0.0.4"));
        assert!(resp.contains("anytime_sgd_net_bytes_sent 7\n"));
        server.shutdown();
        crate::obs::metrics::reset();
    }
}
