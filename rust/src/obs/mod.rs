//! `obs` — std-only observability: spans, metrics, leveled logging,
//! and post-run reports (DESIGN.md §8).
//!
//! The paper's whole argument is a time accounting — fixed-time epochs,
//! honest straggler charges, per-worker utilization — and this module
//! is where the repo *measures* that accounting instead of only
//! simulating it. Three pillars, one switch:
//!
//! * [`span`] — a scoped-span tracer with per-thread buffers and
//!   monotonic timestamps, drained to Chrome trace-event JSON
//!   (loadable in Perfetto / `chrome://tracing`). The trainer, all
//!   three runtimes, the dist wire, and the sweep runner are
//!   instrumented; `train --trace <path>` writes the file.
//! * [`metrics`] — process-wide atomic counters / gauges / f64 sums /
//!   histograms behind a name-keyed registry, snapshot-able at any
//!   point as a stable-key JSON artifact (`train --metrics <path>`).
//! * [`report`] — [`report::RunReport`], the post-run paper-native
//!   accounting (per-worker utilization, straggler attribution,
//!   compute/comm/gather-stall breakdown, bytes per epoch) rendered as
//!   a terminal table and written next to the figures
//!   (`train --report`).
//!
//! [`log`] is the fourth, always-on piece: a leveled stderr logger
//! filtered by the `ANYTIME_SGD_LOG` env var (default `info`), which
//! replaced the net layer's ad-hoc `eprintln!`s.
//!
//! ## The distributed plane (wire v4)
//!
//! Under `--runtime dist` the plane spans processes: workers ship
//! their span buffers and metrics snapshots to the master in
//! `Telemetry` frames, heartbeat echoes give every link an RTT/offset
//! estimate, and the master rebases worker timestamps onto its own
//! [`std::time::Instant`] timeline so `--trace` writes ONE merged
//! Perfetto trace with per-process tracks and dispatch→compute→gather
//! flow arrows ([`span::merge_external`]). Three live surfaces read
//! the same state: [`telemetry`] (the fleet store), [`prometheus`]
//! (`/metrics` text exposition over a std-only `TcpListener`), and
//! [`watch`] (the `--watch` stderr ticker + `status.jsonl`). The
//! contract is in DESIGN.md §8.
//!
//! ## The overhead contract
//!
//! Spans and metrics are **off by default** and gated on one global
//! [`AtomicBool`]: disabled, every record call is a single relaxed
//! load and an early return — no allocation, no locks, no syscalls.
//! Enabled or not, the subsystem reads time exclusively from
//! [`std::time::Instant`]: it never advances [`crate::sim::SimClock`]
//! and never touches an RNG stream, so the sim≡real≡dist bit-exactness
//! pins and the golden traces are identical with observability on or
//! off (pinned by `rust/tests/obs_integration.rs`).

pub mod log;
pub mod metrics;
pub mod prometheus;
pub mod report;
pub mod span;
pub mod telemetry;
pub mod watch;

use std::sync::atomic::{AtomicBool, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn span/metric collection on (process-wide). Flip it before
/// constructing the trainer so admission/handshake spans are captured.
pub fn enable() {
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn span/metric collection off again (tests; already-recorded
/// events stay buffered until [`span::take_events`]).
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Is collection on? Record paths check this first so the disabled
/// cost is one relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Serialize tests that toggle the process-global obs state (the unit
/// tests of [`span`]/[`metrics`] share one lock; integration tests in
/// their own binary carry their own).
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}
