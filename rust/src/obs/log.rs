//! Leveled, env-filtered stderr logging (`ANYTIME_SGD_LOG`).
//!
//! The one logging substrate for the repo's diagnostics — the dist
//! master/worker and the CLI route everything here instead of ad-hoc
//! `eprintln!`s, so runs are quiet by default and debuggable on demand:
//!
//! ```bash
//! ANYTIME_SGD_LOG=debug anytime-sgd train --runtime dist ...
//! ANYTIME_SGD_LOG=off   anytime-sgd sweep ...   # fully silent stderr
//! ```
//!
//! Levels (`off < error < warn < info < debug < trace`) parse from the
//! env var once and cache in an atomic; the default is `info`. The
//! [`crate::log_error!`]..[`crate::log_trace!`] macros are the call
//! sites' interface — formatting cost is only paid when the level is
//! enabled (the gate is checked before `eprintln!` runs).
//!
//! Unlike spans/metrics this pillar is *not* gated on
//! [`crate::obs::enabled`]: a lost dist worker must be reportable even
//! in an un-instrumented run.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};

/// The env var the threshold is read from.
pub const ENV_VAR: &str = "ANYTIME_SGD_LOG";

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    /// Lowercase name, as printed in the line prefix.
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// `off` as a threshold value (no `Level` is ≤ 0).
pub const OFF: u8 = 0;
const DEFAULT: u8 = Level::Info as u8;
const UNSET: u8 = u8::MAX;

static THRESHOLD: AtomicU8 = AtomicU8::new(UNSET);

/// Parse a threshold name (`off|error|warn|info|debug|trace`, plus a
/// couple of tolerated aliases). `None` = unrecognized.
pub fn parse_level(s: &str) -> Option<u8> {
    match s.trim().to_ascii_lowercase().as_str() {
        "off" | "none" | "0" => Some(OFF),
        "error" => Some(Level::Error as u8),
        "warn" | "warning" => Some(Level::Warn as u8),
        "info" => Some(Level::Info as u8),
        "debug" => Some(Level::Debug as u8),
        "trace" => Some(Level::Trace as u8),
        _ => None,
    }
}

fn threshold() -> u8 {
    let t = THRESHOLD.load(Ordering::Relaxed);
    if t != UNSET {
        return t;
    }
    let t = std::env::var(ENV_VAR).ok().and_then(|s| parse_level(&s)).unwrap_or(DEFAULT);
    THRESHOLD.store(t, Ordering::Relaxed);
    t
}

/// Override the threshold programmatically (tests / embedders). Use
/// [`OFF`] to silence everything; [`reset_threshold`] to re-read the
/// env on next use.
pub fn set_threshold(t: u8) {
    THRESHOLD.store(t.min(Level::Trace as u8), Ordering::Relaxed);
}

/// Forget the cached threshold so the next log call re-reads `ENV_VAR`.
pub fn reset_threshold() {
    THRESHOLD.store(UNSET, Ordering::Relaxed);
}

/// Would a message at `level` be emitted right now?
#[inline]
pub fn enabled(level: Level) -> bool {
    level as u8 <= threshold()
}

/// Emit one line: `[level target] message`. Prefer the macros — they
/// skip argument formatting when the level is filtered out.
pub fn log(level: Level, target: &str, msg: fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{:<5} {target}] {msg}", level.name());
    }
}

/// Log at `error`: `log_error!("net", "lost worker {}", v)`.
#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)+) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::log($crate::obs::log::Level::Error, $target, format_args!($($arg)+));
        }
    };
}

/// Log at `warn`: `log_warn!("net", "rejected: {e:#}")`.
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)+) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::log($crate::obs::log::Level::Warn, $target, format_args!($($arg)+));
        }
    };
}

/// Log at `info` (the default threshold).
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)+) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::log($crate::obs::log::Level::Info, $target, format_args!($($arg)+));
        }
    };
}

/// Log at `debug` (hidden unless `ANYTIME_SGD_LOG=debug` or chattier).
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)+) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::log($crate::obs::log::Level::Debug, $target, format_args!($($arg)+));
        }
    };
}

/// Log at `trace` (the chattiest tier).
#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)+) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Trace) {
            $crate::obs::log::log($crate::obs::log::Level::Trace, $target, format_args!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_level_name() {
        assert_eq!(parse_level("off"), Some(OFF));
        assert_eq!(parse_level("ERROR"), Some(1));
        assert_eq!(parse_level(" warn "), Some(2));
        assert_eq!(parse_level("info"), Some(3));
        assert_eq!(parse_level("debug"), Some(4));
        assert_eq!(parse_level("trace"), Some(5));
        assert_eq!(parse_level("verbose"), None);
    }

    #[test]
    fn threshold_gates_levels() {
        let _g = crate::obs::test_lock();
        set_threshold(Level::Warn as u8);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_threshold(OFF);
        assert!(!enabled(Level::Error));
        // The macros compile and are no-ops below threshold.
        crate::log_debug!("test", "invisible {}", 42);
        reset_threshold();
    }
}
