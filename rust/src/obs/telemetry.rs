//! Fleet telemetry store: the master-side view of every worker's last
//! `Telemetry` frame and heartbeat-derived link clock (DESIGN.md §8).
//!
//! The dist master feeds this from two places: continuously from each
//! heartbeat's piggybacked RTT/offset estimate ([`record_link`]), and
//! at epoch boundaries / shutdown from the worker's `Telemetry` frame
//! ([`record_worker`]) which also carries the worker's own metrics
//! snapshot and span-drop count. The live surfaces — the Prometheus
//! `/metrics` endpoint ([`crate::obs::prometheus`]) and the `--watch`
//! ticker ([`crate::obs::watch`]) — read the fleet back with
//! [`fleet`].
//!
//! Worker metrics are kept *per worker* here rather than merged into
//! the process-wide [`crate::obs::metrics`] registry: the master
//! already aggregates fleet totals on its own instruments, and merging
//! would double-count bytes and busy-seconds. Everything is behind the
//! caller's `obs::enabled()` gate and touches only wall-clock-free
//! state, so the obs-on ≡ obs-off bit-exactness pin is unaffected.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

/// Last-known telemetry for one worker, keyed by worker index.
#[derive(Clone, Debug, Default)]
pub struct WorkerTelemetry {
    /// Round stamped on the most recent `Telemetry` frame.
    pub round: u64,
    /// Min-filtered link round-trip estimate in µs (0 = none yet).
    pub rtt_us: u64,
    /// Estimated worker→master clock offset in µs (meaningless while
    /// `rtt_us == 0`).
    pub offset_us: i64,
    /// Cumulative span-buffer drop count reported by the worker.
    pub dropped: u64,
    /// The worker's flattened metrics snapshot (`name -> value`),
    /// stable-ordered for deterministic rendering.
    pub metrics: BTreeMap<String, f64>,
}

fn store() -> &'static Mutex<BTreeMap<u32, WorkerTelemetry>> {
    static STORE: OnceLock<Mutex<BTreeMap<u32, WorkerTelemetry>>> = OnceLock::new();
    STORE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Continuous path: fold one heartbeat's piggybacked link estimate in.
/// Keeps the minimum-RTT sample (least queueing ⇒ best offset).
pub fn record_link(worker: u32, rtt_us: u64, offset_us: i64) {
    if rtt_us == 0 {
        return; // worker has no estimate yet
    }
    let mut s = store().lock().unwrap_or_else(|e| e.into_inner());
    let w = s.entry(worker).or_default();
    if w.rtt_us == 0 || rtt_us <= w.rtt_us {
        w.rtt_us = rtt_us;
        w.offset_us = offset_us;
    }
}

/// Epoch-boundary path: absorb a full `Telemetry` frame's summary
/// (round, drop count, metrics snapshot; the spans themselves go to
/// [`crate::obs::span::merge_external`], not here).
pub fn record_worker(worker: u32, round: u64, dropped: u64, metrics: &[(String, f64)]) {
    let mut s = store().lock().unwrap_or_else(|e| e.into_inner());
    let w = s.entry(worker).or_default();
    w.round = w.round.max(round);
    w.dropped = w.dropped.max(dropped); // cumulative on the worker side
    for (k, v) in metrics {
        w.metrics.insert(k.clone(), *v);
    }
}

/// Snapshot the whole fleet (cloned; callers render without the lock).
pub fn fleet() -> BTreeMap<u32, WorkerTelemetry> {
    store().lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Drop all fleet state (tests / between sweep cells).
pub fn clear() {
    store().lock().unwrap_or_else(|e| e.into_inner()).clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_estimates_keep_the_min_rtt_sample() {
        let _g = crate::obs::test_lock();
        clear();
        record_link(2, 0, 99); // no estimate: ignored
        record_link(2, 500, 10);
        record_link(2, 900, 77); // worse RTT: offset not overwritten
        record_link(2, 400, -3); // better RTT: wins
        let f = fleet();
        assert_eq!(f[&2].rtt_us, 400);
        assert_eq!(f[&2].offset_us, -3);
        clear();
    }

    #[test]
    fn worker_frames_merge_cumulatively() {
        let _g = crate::obs::test_lock();
        clear();
        record_worker(1, 3, 0, &[("worker.busy_secs".into(), 1.5)]);
        record_worker(1, 5, 7, &[("worker.busy_secs".into(), 2.5)]);
        record_worker(1, 4, 7, &[]); // stale round: round keeps max
        let f = fleet();
        assert_eq!(f[&1].round, 5);
        assert_eq!(f[&1].dropped, 7);
        assert_eq!(f[&1].metrics["worker.busy_secs"], 2.5);
        clear();
    }
}
