//! Command-line parsing substrate (no `clap` offline).
//!
//! A declarative-enough flag parser: define a [`Command`] with typed
//! [`FlagSpec`]s, parse `--flag value` / `--flag=value` / bare
//! positionals, get defaults, validation, and generated `--help` text.

use std::collections::BTreeMap;
use std::fmt;

/// Parse error with the offending token.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

/// Flag value types.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlagKind {
    Bool,
    Int,
    Float,
    Str,
}

/// One flag's declaration.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub kind: FlagKind,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// A (sub)command: name, description, flag table.
#[derive(Debug, Clone)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, flags: Vec::new() }
    }

    pub fn flag(
        mut self,
        name: &'static str,
        kind: FlagKind,
        default: Option<&'static str>,
        help: &'static str,
    ) -> Self {
        assert!(
            !self.flags.iter().any(|f| f.name == name),
            "duplicate flag --{name}"
        );
        self.flags.push(FlagSpec { name, kind, default, help });
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nFlags:\n", self.name, self.about);
        for f in &self.flags {
            let kind = match f.kind {
                FlagKind::Bool => "",
                FlagKind::Int => " <int>",
                FlagKind::Float => " <float>",
                FlagKind::Str => " <str>",
            };
            let default = f
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{kind}\n      {}{default}\n", f.name, f.help));
        }
        s
    }

    /// Parse an argument list (without the command name itself).
    pub fn parse(&self, args: &[String]) -> Result<Matches, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            if arg == "--help" || arg == "-h" {
                return Err(CliError(self.help()));
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError(format!("unknown flag --{name} (try --help)")))?;
                let raw = match (spec.kind, inline) {
                    (FlagKind::Bool, None) => "true".to_string(),
                    (FlagKind::Bool, Some(v)) => v,
                    (_, Some(v)) => v,
                    (_, None) => {
                        i += 1;
                        args.get(i)
                            .cloned()
                            .ok_or_else(|| CliError(format!("--{name} expects a value")))?
                    }
                };
                validate(spec, &raw)?;
                values.insert(name.to_string(), raw);
            } else {
                positional.push(arg.clone());
            }
            i += 1;
        }
        // Fill defaults.
        for spec in &self.flags {
            if !values.contains_key(spec.name) {
                if let Some(d) = spec.default {
                    values.insert(spec.name.to_string(), d.to_string());
                }
            }
        }
        Ok(Matches { values, positional })
    }
}

fn validate(spec: &FlagSpec, raw: &str) -> Result<(), CliError> {
    let ok = match spec.kind {
        FlagKind::Bool => matches!(raw, "true" | "false" | "1" | "0"),
        FlagKind::Int => raw.parse::<i64>().is_ok(),
        FlagKind::Float => raw.parse::<f64>().is_ok(),
        FlagKind::Str => true,
    };
    if ok {
        Ok(())
    } else {
        Err(CliError(format!("--{}: invalid {:?} value {raw:?}", spec.name, spec.kind)))
    }
}

/// Parsed flag values + positionals.
#[derive(Debug, Clone, Default)]
pub struct Matches {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn str_of(&self, name: &str) -> String {
        self.values.get(name).cloned().unwrap_or_default()
    }
    pub fn usize_of(&self, name: &str) -> usize {
        self.values.get(name).and_then(|v| v.parse().ok()).unwrap_or_else(|| panic!("flag --{name} missing/invalid"))
    }
    pub fn u64_of(&self, name: &str) -> u64 {
        self.values.get(name).and_then(|v| v.parse().ok()).unwrap_or_else(|| panic!("flag --{name} missing/invalid"))
    }
    pub fn f64_of(&self, name: &str) -> f64 {
        self.values.get(name).and_then(|v| v.parse().ok()).unwrap_or_else(|| panic!("flag --{name} missing/invalid"))
    }
    pub fn bool_of(&self, name: &str) -> bool {
        matches!(self.values.get(name).map(|s| s.as_str()), Some("true") | Some("1"))
    }
    pub fn is_set(&self, name: &str) -> bool {
        self.values.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "run a training job")
            .flag("workers", FlagKind::Int, Some("10"), "worker count")
            .flag("t", FlagKind::Float, Some("100.0"), "epoch budget seconds")
            .flag("verbose", FlagKind::Bool, None, "chatty output")
            .flag("method", FlagKind::Str, Some("anytime"), "method name")
    }

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let m = cmd().parse(&[]).unwrap();
        assert_eq!(m.usize_of("workers"), 10);
        assert_eq!(m.f64_of("t"), 100.0);
        assert!(!m.bool_of("verbose"));
        assert_eq!(m.str_of("method"), "anytime");
    }

    #[test]
    fn space_and_equals_forms() {
        let m = cmd().parse(&argv(&["--workers", "20", "--t=3.5", "--verbose"])).unwrap();
        assert_eq!(m.usize_of("workers"), 20);
        assert_eq!(m.f64_of("t"), 3.5);
        assert!(m.bool_of("verbose"));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(cmd().parse(&argv(&["--nope", "1"])).is_err());
    }

    #[test]
    fn invalid_typed_value_rejected() {
        assert!(cmd().parse(&argv(&["--workers", "many"])).is_err());
        assert!(cmd().parse(&argv(&["--t", "fast"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&argv(&["--workers"])).is_err());
    }

    #[test]
    fn positionals_collected() {
        let m = cmd().parse(&argv(&["fig3", "--workers", "4", "out.csv"])).unwrap();
        assert_eq!(m.positional, vec!["fig3".to_string(), "out.csv".to_string()]);
    }

    #[test]
    fn help_lists_flags() {
        let h = cmd().help();
        assert!(h.contains("--workers"));
        assert!(h.contains("default: 10"));
    }

    #[test]
    #[should_panic]
    fn duplicate_flag_panics() {
        Command::new("x", "y")
            .flag("a", FlagKind::Int, None, "")
            .flag("a", FlagKind::Int, None, "");
    }
}
