//! Structured run telemetry: JSONL event stream.
//!
//! Production framing for the coordinator: every epoch emits one JSON
//! line with the χ set, q profile, λ, time charges and evaluation — the
//! artifact a downstream dashboard (or a debugging session) consumes.
//! Enabled from the CLI with `train --events <path>`.

use crate::coordinator::EpochStats;
use crate::ser::Value;
use std::io::Write;
use std::path::Path;

/// JSONL sink for run events.
pub struct EventLog {
    out: std::io::BufWriter<std::fs::File>,
    lines: usize,
}

impl EventLog {
    /// Create (truncate) the log file.
    pub fn create(path: &Path) -> std::io::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(Self { out: std::io::BufWriter::new(std::fs::File::create(path)?), lines: 0 })
    }

    fn emit(&mut self, v: &Value) -> std::io::Result<()> {
        // True one-line form: the compact writer escapes newlines
        // inside string values, so a newline-bearing run name can't
        // split a record across JSONL lines.
        let text = crate::ser::to_string_compact(v);
        writeln!(self.out, "{text}")?;
        self.lines += 1;
        Ok(())
    }

    /// Run header.
    pub fn run_started(&mut self, name: &str, workers: usize, seed: u64) -> std::io::Result<()> {
        self.emit(&Value::obj(vec![
            ("event", "run_started".into()),
            ("name", name.into()),
            ("workers", workers.into()),
            ("seed", Value::Num(seed as f64)),
        ]))
    }

    /// One epoch's protocol outcome.
    pub fn epoch(&mut self, e: usize, stats: &EpochStats, sim_time: f64) -> std::io::Result<()> {
        self.emit(&Value::obj(vec![
            ("event", "epoch".into()),
            ("epoch", e.into()),
            ("sim_time", sim_time.into()),
            ("q", Value::Arr(stats.q.iter().map(|&q| q.into()).collect())),
            ("received", Value::Arr(stats.received.iter().map(|&r| r.into()).collect())),
            ("lambda", Value::nums(&stats.lambda.iter().map(|&l| l).collect::<Vec<f64>>())),
            ("compute_secs", stats.compute_secs.into()),
            ("comm_secs", stats.comm_secs.into()),
            (
                "worker_finish",
                Value::Arr(
                    stats
                        .worker_finish
                        .iter()
                        .map(|f| match f {
                            Some(t) => Value::Num(*t),
                            None => Value::Null,
                        })
                        .collect(),
                ),
            ),
        ]))
    }

    /// One epoch's real communication cost (networked runtimes only):
    /// frame bytes both ways, per-worker task→report round trips in
    /// real seconds, and reports that never made it into a gather.
    pub fn net(
        &mut self,
        e: usize,
        net: &crate::coordinator::runtime::NetEpochStats,
    ) -> std::io::Result<()> {
        self.emit(&Value::obj(vec![
            ("event", "net".into()),
            ("epoch", e.into()),
            ("bytes_sent", Value::Num(net.bytes_sent as f64)),
            ("bytes_recv", Value::Num(net.bytes_recv as f64)),
            (
                "rtt_secs",
                Value::Arr(
                    net.rtt_secs
                        .iter()
                        .map(|r| match r {
                            Some(t) => Value::Num(*t),
                            None => Value::Null,
                        })
                        .collect(),
                ),
            ),
            ("dropped_reports", net.dropped_reports.into()),
        ]))
    }

    /// An evaluation point. `objective` names the metric's semantics
    /// (per-objective cost/error — DESIGN.md §7).
    pub fn eval(
        &mut self,
        e: usize,
        norm_err: f64,
        cost: f64,
        objective: &str,
    ) -> std::io::Result<()> {
        self.emit(&Value::obj(vec![
            ("event", "eval".into()),
            ("epoch", e.into()),
            ("norm_err", norm_err.into()),
            ("cost", cost.into()),
            ("objective", objective.into()),
        ]))
    }

    /// Run footer; flushes.
    pub fn run_finished(&mut self, final_err: f64) -> std::io::Result<()> {
        self.emit(&Value::obj(vec![
            ("event", "run_finished".into()),
            ("final_err", final_err.into()),
        ]))?;
        self.out.flush()
    }

    /// Lines written so far.
    pub fn lines(&self) -> usize {
        self.lines
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_parseable_jsonl() {
        let path = std::env::temp_dir().join(format!("anytime-events-{}.jsonl", std::process::id()));
        {
            let mut log = EventLog::create(&path).unwrap();
            log.run_started("test", 4, 42).unwrap();
            let stats = EpochStats {
                q: vec![10, 0, 5],
                received: vec![true, false, true],
                compute_secs: 20.0,
                comm_secs: 2.0,
                lambda: vec![0.66, 0.0, 0.34],
                worker_finish: vec![Some(20.5), None, Some(21.0)],
            };
            log.epoch(0, &stats, 22.0).unwrap();
            log.net(
                0,
                &crate::coordinator::runtime::NetEpochStats {
                    bytes_sent: 2048,
                    bytes_recv: 512,
                    rtt_secs: vec![Some(0.004), None, Some(0.006)],
                    dropped_reports: 1,
                },
            )
            .unwrap();
            log.eval(0, 0.5, 123.0, "linreg").unwrap();
            log.run_finished(0.5).unwrap();
            assert_eq!(log.lines(), 5);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        for line in &lines {
            let v = crate::ser::parse(line).unwrap();
            assert!(v.get_str("event").is_some());
        }
        let epoch = crate::ser::parse(lines[1]).unwrap();
        assert_eq!(epoch.get_str("event"), Some("epoch"));
        assert_eq!(epoch.get("q").unwrap().as_arr().unwrap().len(), 3);
        let wf = epoch.get("worker_finish").unwrap().as_arr().unwrap();
        assert_eq!(wf.len(), 3);
        assert_eq!(wf[0].as_f64(), Some(20.5));
        assert_eq!(wf[1], crate::ser::Value::Null);
        let net = crate::ser::parse(lines[2]).unwrap();
        assert_eq!(net.get_str("event"), Some("net"));
        assert_eq!(net.get_f64("bytes_sent"), Some(2048.0));
        assert_eq!(net.get_usize("dropped_reports"), Some(1));
        let rtt = net.get("rtt_secs").unwrap().as_arr().unwrap();
        assert_eq!(rtt.len(), 3);
        assert_eq!(rtt[0].as_f64(), Some(0.004));
        assert_eq!(rtt[1], crate::ser::Value::Null);
        let eval = crate::ser::parse(lines[3]).unwrap();
        assert_eq!(eval.get_str("objective"), Some("linreg"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn newline_bearing_strings_stay_one_line() {
        // Regression: the old emit() compacted via replace('\n', " ")
        // on the pretty form, which split any record whose *string
        // values* contained newlines — and corrupted the value itself.
        let path =
            std::env::temp_dir().join(format!("anytime-events-nl-{}.jsonl", std::process::id()));
        let name = "multi\nline \"name\"";
        {
            let mut log = EventLog::create(&path).unwrap();
            log.run_started(name, 2, 7).unwrap();
            log.run_finished(0.25).unwrap();
            assert_eq!(log.lines(), 2);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "one JSONL line per event: {text:?}");
        let header = crate::ser::parse(lines[0]).unwrap();
        assert_eq!(header.get_str("event"), Some("run_started"));
        assert_eq!(header.get_str("name"), Some(name), "newline must survive the round trip");
        std::fs::remove_file(path).ok();
    }
}
