//! Run traces, series, and CSV/JSON emitters for the figure harness.

pub mod events;

use crate::ser::Value;
use std::fmt::Write as _;
use std::path::Path;

/// One evaluated point on a convergence curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TracePoint {
    pub epoch: usize,
    /// Simulated wall-clock seconds at the end of this epoch.
    pub time: f64,
    /// Normalized error ‖A(x−x*)‖/‖Ax*‖.
    pub norm_err: f64,
    /// Cost F(x) (eq. 1).
    pub cost: f64,
    /// Total steps Σ_v q_v this epoch.
    pub total_q: usize,
}

/// A labeled convergence curve.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub label: String,
    pub points: Vec<TracePoint>,
}

impl Trace {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), points: Vec::new() }
    }

    /// First simulated time at which the error drops below `target`
    /// (linear interpolation between epochs), or None.
    ///
    /// Trainer traces carry their run origin as the epoch-0 point
    /// `(t = 0, initial error)`, so the scan starts from it. For a
    /// trace that begins mid-run (first point at t > 0) the origin
    /// error is unknown here — use [`Trace::time_to_error_from`] with
    /// the run's initial error so a first point that already meets the
    /// target is interpolated from t = 0 instead of being credited
    /// with its full first-interval time.
    pub fn time_to_error(&self, target: f64) -> Option<f64> {
        match self.points.first() {
            Some(p0) if p0.time == 0.0 => self.time_to_error_from(p0.norm_err, target),
            _ => self.time_to_error_from(f64::INFINITY, target),
        }
    }

    /// [`Trace::time_to_error`] seeded with an explicit run origin
    /// `(t = 0, initial_err)` — for traces that do not store the
    /// epoch-0 point. An infinite `initial_err` disables origin
    /// interpolation (the first meeting point's own time is returned).
    pub fn time_to_error_from(&self, initial_err: f64, target: f64) -> Option<f64> {
        if initial_err <= target {
            return Some(0.0);
        }
        let mut prev: (f64, f64) = (0.0, initial_err);
        for p in &self.points {
            if p.norm_err <= target {
                let (t0, e0) = prev;
                return Some(if e0.is_finite() && e0 > p.norm_err {
                    let f = (e0 - target) / (e0 - p.norm_err);
                    t0 + f * (p.time - t0)
                } else {
                    p.time
                });
            }
            prev = (p.time, p.norm_err);
        }
        None
    }

    /// Final error.
    pub fn final_err(&self) -> f64 {
        self.points.last().map(|p| p.norm_err).unwrap_or(f64::INFINITY)
    }
}

/// A figure: several traces over a shared x-axis.
#[derive(Clone, Debug, Default)]
pub struct Figure {
    pub name: String,
    pub x_axis: String,
    /// The error metric on the y-axis — per-objective (e.g.
    /// `‖Ax − Ax*‖/‖Ax*‖` for least squares, k-class logit distance for
    /// softmax). Defaults to the generic `norm_err`.
    pub y_label: String,
    pub traces: Vec<Trace>,
}

impl Figure {
    pub fn new(name: impl Into<String>, x_axis: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            x_axis: x_axis.into(),
            y_label: "norm_err".into(),
            traces: Vec::new(),
        }
    }

    /// Builder-style y-axis metric label (the objective registry's
    /// `metric` string).
    pub fn with_y_label(mut self, label: impl Into<String>) -> Self {
        self.y_label = label.into();
        self
    }

    /// CSV rows: label,epoch,time,norm_err,cost,total_q.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("label,epoch,time,norm_err,cost,total_q\n");
        for t in &self.traces {
            for p in &t.points {
                let _ = writeln!(
                    out,
                    "{},{},{:.6},{:.6e},{:.6e},{}",
                    t.label, p.epoch, p.time, p.norm_err, p.cost, p.total_q
                );
            }
        }
        out
    }

    /// JSON dump (stable key order via ser::Value).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", self.name.as_str().into()),
            ("x_axis", self.x_axis.as_str().into()),
            ("y_label", self.y_label.as_str().into()),
            (
                "traces",
                Value::Arr(
                    self.traces
                        .iter()
                        .map(|t| {
                            Value::obj(vec![
                                ("label", t.label.as_str().into()),
                                (
                                    "points",
                                    Value::Arr(
                                        t.points
                                            .iter()
                                            .map(|p| {
                                                Value::obj(vec![
                                                    ("epoch", p.epoch.into()),
                                                    ("time", p.time.into()),
                                                    ("norm_err", p.norm_err.into()),
                                                    ("cost", p.cost.into()),
                                                    ("total_q", p.total_q.into()),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `<dir>/<name>.csv` and `.json`; returns the csv path.
    pub fn write(&self, dir: &Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let csv = dir.join(format!("{}.csv", self.name));
        std::fs::write(&csv, self.to_csv())?;
        let json = dir.join(format!("{}.json", self.name));
        std::fs::write(json, crate::ser::to_string_pretty(&self.to_json()))?;
        Ok(csv)
    }

    /// Terminal rendering: one row per epoch, log-error columns.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        if self.y_label.is_empty() || self.y_label == "norm_err" {
            let _ = writeln!(out, "== {} (x = {}) ==", self.name, self.x_axis);
        } else {
            let _ = writeln!(out, "== {} (x = {}, err = {}) ==", self.name, self.x_axis, self.y_label);
        }
        let _ = write!(out, "{:>8}", self.x_axis);
        for t in &self.traces {
            let _ = write!(out, "{:>24}", t.label);
        }
        out.push('\n');
        let rows = self.traces.iter().map(|t| t.points.len()).max().unwrap_or(0);
        for i in 0..rows {
            let x = self
                .traces
                .iter()
                .find_map(|t| t.points.get(i))
                .map(|p| if self.x_axis == "epoch" { p.epoch as f64 } else { p.time })
                .unwrap_or(0.0);
            let _ = write!(out, "{x:>8.1}");
            for t in &self.traces {
                match t.points.get(i) {
                    Some(p) => {
                        let _ = write!(out, "    err={:>9.3e} (t={:>7.1})", p.norm_err, p.time);
                    }
                    None => {
                        let _ = write!(out, "{:>24}", "-");
                    }
                }
            }
            out.push('\n');
        }
        out
    }
}

/// Simple fixed-width histogram (Fig. 1 reproduction).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<usize>,
    pub overflow: usize,
    /// Samples below `lo` (previously folded silently into bin 0 by
    /// the saturating float→usize cast).
    pub underflow: usize,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self { lo, hi, counts: vec![0; bins], overflow: 0, underflow: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        if x >= self.hi {
            self.overflow += 1;
            return;
        }
        let f = (x - self.lo) / (self.hi - self.lo);
        let b = ((f * self.counts.len() as f64) as usize).min(self.counts.len() - 1);
        self.counts[b] += 1;
    }

    pub fn total(&self) -> usize {
        self.counts.iter().sum::<usize>() + self.overflow + self.underflow
    }

    /// ASCII rendering with bin ranges and bars.
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let bw = (self.hi - self.lo) / self.counts.len() as f64;
        let mut out = String::new();
        let _ = writeln!(out, "<{:<14.1} {:>6} (below)", self.lo, self.underflow);
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat(c * width / max);
            let _ = writeln!(
                out,
                "{:>7.1}-{:<7.1} {:>6} {bar}",
                self.lo + i as f64 * bw,
                self.lo + (i + 1) as f64 * bw,
                c
            );
        }
        let _ = writeln!(out, ">{:<14.1} {:>6} (tail)", self.hi, self.overflow);
        out
    }

    /// CSV rows: bin_lo,bin_hi,count.
    pub fn to_csv(&self) -> String {
        let bw = (self.hi - self.lo) / self.counts.len() as f64;
        let mut out = String::from("bin_lo,bin_hi,count\n");
        let _ = writeln!(out, "-inf,{:.4},{}", self.lo, self.underflow);
        for (i, &c) in self.counts.iter().enumerate() {
            let _ = writeln!(out, "{:.4},{:.4},{c}", self.lo + i as f64 * bw, self.lo + (i + 1) as f64 * bw);
        }
        let _ = writeln!(out, "{:.4},inf,{}", self.hi, self.overflow);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(points: &[(f64, f64)]) -> Trace {
        Trace {
            label: "t".into(),
            points: points
                .iter()
                .enumerate()
                .map(|(i, &(time, err))| TracePoint { epoch: i, time, norm_err: err, cost: 0.0, total_q: 0 })
                .collect(),
        }
    }

    #[test]
    fn time_to_error_interpolates() {
        let t = trace(&[(0.0, 1.0), (10.0, 0.5), (20.0, 0.1)]);
        assert_eq!(t.time_to_error(0.5), Some(10.0));
        // 0.3 is 50% between 0.5 and 0.1 -> t = 15.
        assert!((t.time_to_error(0.3).unwrap() - 15.0).abs() < 1e-9);
        assert_eq!(t.time_to_error(0.01), None);
        assert_eq!(t.final_err(), 0.1);
        // A target the origin already meets is reached at t = 0.
        assert_eq!(t.time_to_error(1.0), Some(0.0));
    }

    #[test]
    fn time_to_error_interpolates_from_the_run_origin() {
        // A trace that starts mid-run: first eval point (t=12) already
        // meets the target. With the run origin supplied, the crossing
        // is interpolated from (0, initial) instead of credited with
        // the full first-epoch time.
        let t = trace(&[(12.0, 0.3), (24.0, 0.1)]);
        let got = t.time_to_error_from(1.0, 0.5).unwrap();
        assert!((got - 12.0 * (0.5 / 0.7)).abs() < 1e-9, "{got}");
        // Origin at/below the target: met at t = 0.
        assert_eq!(t.time_to_error_from(0.5, 0.5), Some(0.0));
        // Without origin information, fall back to the point's time.
        assert_eq!(t.time_to_error(0.5), Some(12.0));
        // Origin seeding never changes later crossings' interpolation.
        assert!((t.time_to_error_from(1.0, 0.2).unwrap() - 18.0).abs() < 1e-9);
    }

    #[test]
    fn csv_has_all_rows() {
        let mut f = Figure::new("fig_test", "time");
        f.traces.push(trace(&[(0.0, 1.0), (1.0, 0.5)]));
        let csv = f.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("label,epoch"));
    }

    #[test]
    fn figure_write_and_json(){
        let dir = std::env::temp_dir().join(format!("anytime-metrics-{}", std::process::id()));
        let mut f = Figure::new("fig_x", "time").with_y_label("‖Z − Z*‖/‖Z*‖");
        f.traces.push(trace(&[(0.0, 1.0)]));
        let p = f.write(&dir).unwrap();
        assert!(p.exists());
        let json = std::fs::read_to_string(dir.join("fig_x.json")).unwrap();
        let v = crate::ser::parse(&json).unwrap();
        assert_eq!(v.get_str("name"), Some("fig_x"));
        assert_eq!(v.get_str("y_label"), Some("‖Z − Z*‖/‖Z*‖"));
        assert!(f.render_table().contains("err = ‖Z − Z*‖/‖Z*‖"));
        // The default label keeps the historical header.
        assert!(!Figure::new("plain", "time").render_table().contains("err ="));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn histogram_bins_and_overflow() {
        let mut h = Histogram::new(0.0, 100.0, 10);
        for x in [5.0, 15.0, 15.5, 99.9, 150.0] {
            h.add(x);
        }
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.underflow, 0);
        assert_eq!(h.total(), 5);
        assert!(h.render(40).contains("(tail)"));
        // header + underflow row + 10 bins + overflow row
        assert!(h.to_csv().lines().count() == 13);
    }

    #[test]
    fn histogram_counts_underflow_separately() {
        let mut h = Histogram::new(10.0, 20.0, 5);
        h.add(9.9);
        h.add(-3.0);
        h.add(10.0);
        assert_eq!(h.underflow, 2, "below-lo samples must not fold into bin 0");
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.total(), 3);
        assert!(h.render(40).contains("(below)"));
        let csv = h.to_csv();
        assert_eq!(csv.lines().nth(1).unwrap(), "-inf,10.0000,2");
    }
}
