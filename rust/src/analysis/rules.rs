//! The contract rules of the static-analysis pass (DESIGN.md §10).
//!
//! Each rule is a pure function from a [`SourceFile`] (plus whatever
//! cross-file inputs it needs) to machine-readable [`Finding`]s. The
//! repo driver in [`super`] wires them to the real tree; the fixture
//! self-tests in `rust/tests/analysis_gate.rs` wire them to known-bad
//! samples under `rust/tests/analysis_fixtures/` to prove each rule
//! actually fires.

use super::fingerprint::{self, Pin};
use super::source::{find_token, SourceFile};

/// One machine-readable finding: `file:line: [rule] msg`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Rule metadata, for `lint` output and DESIGN.md's rule table.
pub struct RuleInfo {
    pub id: &'static str,
    pub about: &'static str,
}

/// Every rule the pass runs (plus the synthetic `waiver-unused`).
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "det-time",
        about: "wall-clock/ambient-RNG reads only in allowlisted modules",
    },
    RuleInfo {
        id: "det-order",
        about: "no HashMap/HashSet in result-producing code (iteration order)",
    },
    RuleInfo {
        id: "hostile-panic",
        about: "no unwrap/expect/panic/unchecked indexing in hostile decode paths",
    },
    RuleInfo {
        id: "registry",
        about: "every protocol/objective/compressor/kernel module is registered and documented",
    },
    RuleInfo {
        id: "wire-fingerprint",
        about: "wire-surface changes must bump PROTOCOL_VERSION and re-pin",
    },
    RuleInfo {
        id: "waiver-unused",
        about: "waivers that cover no current finding are stale",
    },
];

/// Modules allowed to read wall clocks / ambient randomness: the
/// real-time execution layers (`sim::RealClock`, the threaded runtime's
/// deadline enforcement, the TCP substrate, process spawning), the
/// observability layer, benchmarking, and the CLI entry point. The
/// numeric core and everything that produces run results must derive
/// all time and randomness from `SimClock` and the seeded RNG tree.
pub const DET_TIME_ALLOW: &[&str] = &[
    "rust/src/sim/",
    "rust/src/obs/",
    "rust/src/net/",
    "rust/src/exec/",
    "rust/src/benchkit/",
    "rust/src/coordinator/runtime.rs",
    "rust/src/main.rs",
];

const DET_TIME_TOKENS: &[&str] = &[
    "Instant::now",
    "SystemTime",
    "UNIX_EPOCH",
    "thread_rng",
    "from_entropy",
    "getrandom",
    "RandomState",
];

/// Rule `det-time`: flag ambient time/randomness reads outside the
/// allowlist. Test code is exempt.
pub fn det_time(src: &SourceFile) -> Vec<Finding> {
    if DET_TIME_ALLOW.iter().any(|p| src.path.starts_with(p)) {
        return Vec::new();
    }
    scan_tokens(src, "det-time", DET_TIME_TOKENS, |tok| {
        format!(
            "`{tok}` outside the real-time allowlist — results must derive time/randomness \
             from SimClock and the seeded RNG tree (DESIGN.md §10)"
        )
    })
}

/// Rule `det-order`: flag `HashMap`/`HashSet` anywhere in non-test
/// library code. Their iteration order is randomized per process, so
/// any result-producing traversal breaks the bit-exactness pins; the
/// tree uses `BTreeMap`/`BTreeSet` (or sorted keys) instead.
pub fn det_order(src: &SourceFile) -> Vec<Finding> {
    scan_tokens(src, "det-order", &["HashMap", "HashSet"], |tok| {
        format!("`{tok}` iterates in randomized order — use BTreeMap/BTreeSet or sorted keys")
    })
}

fn scan_tokens(
    src: &SourceFile,
    rule: &'static str,
    tokens: &[&str],
    msg: impl Fn(&str) -> String,
) -> Vec<Finding> {
    let mut out = Vec::new();
    for (idx, code) in src.code.iter().enumerate() {
        if src.in_test.get(idx).copied().unwrap_or(false) {
            continue;
        }
        for tok in tokens {
            if !find_token(code, tok).is_empty() {
                out.push(Finding {
                    rule,
                    file: src.path.clone(),
                    line: idx + 1,
                    msg: msg(tok),
                });
            }
        }
    }
    out
}

/// Scope of the `hostile-panic` rule within one file.
#[derive(Debug, Clone, Copy)]
pub enum PanicScope<'a> {
    /// Every non-test line of the file.
    WholeFile,
    /// Only the bodies of the named functions.
    Fns(&'a [&'a str]),
}

const PANIC_TOKENS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
    "assert!",
    "assert_eq!",
    "assert_ne!",
];

/// Rule `hostile-panic`: in decode paths fed by sockets/files, flag
/// every panicking construct and every unchecked slice index. Hostile
/// bytes must decode to an error, never abort the process
/// (`debug_assert!` is allowed — it compiles out of release builds).
pub fn hostile_panic(src: &SourceFile, scope: PanicScope<'_>) -> Vec<Finding> {
    let in_scope: Vec<bool> = match scope {
        PanicScope::WholeFile => {
            (0..src.len()).map(|i| !src.in_test.get(i).copied().unwrap_or(false)).collect()
        }
        PanicScope::Fns(names) => {
            let mut mask = vec![false; src.len()];
            for name in names {
                for (start, end) in src.fn_spans(name) {
                    for m in mask.iter_mut().take(end).skip(start - 1) {
                        *m = true;
                    }
                }
            }
            mask
        }
    };
    let mut out = Vec::new();
    for (idx, code) in src.code.iter().enumerate() {
        if !in_scope.get(idx).copied().unwrap_or(false) {
            continue;
        }
        for tok in PANIC_TOKENS {
            if !find_token(code, tok).is_empty() {
                out.push(Finding {
                    rule: "hostile-panic",
                    file: src.path.clone(),
                    line: idx + 1,
                    msg: format!(
                        "`{tok}` in a hostile decode path — corrupt input must error, not abort"
                    ),
                });
            }
        }
        for col in index_sites(code) {
            out.push(Finding {
                rule: "hostile-panic",
                file: src.path.clone(),
                line: idx + 1,
                msg: format!(
                    "unchecked slice index at column {} — use .get()/.get_mut()/try_into()",
                    col + 1
                ),
            });
        }
    }
    out
}

/// Columns of `[` that index into a value: the previous non-space
/// char is an identifier char, `)`, or `]`. Array/type literals
/// (`[0u8; 4]`, `vec![…]`, `#[attr]`) are preceded by other chars and
/// never match; neither does a slice type after a lifetime
/// (`&'a [u8]` — the identifier there is the lifetime's name, not a
/// value).
fn index_sites(code: &str) -> Vec<usize> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    for (i, &c) in b.iter().enumerate() {
        if c != b'[' {
            continue;
        }
        let Some(j) = b.get(..i).and_then(|pre| pre.iter().rposition(|&p| p != b' ')) else {
            continue;
        };
        let p = b.get(j).copied().unwrap_or(b' ');
        if !(p.is_ascii_alphanumeric() || p == b'_' || p == b')' || p == b']') {
            continue;
        }
        if p.is_ascii_alphanumeric() || p == b'_' {
            let run_start = b
                .get(..j)
                .and_then(|pre| {
                    pre.iter().rposition(|&q| !(q.is_ascii_alphanumeric() || q == b'_'))
                });
            if run_start.and_then(|s| b.get(s).copied()) == Some(b'\'') {
                continue;
            }
        }
        out.push(i);
    }
    out
}

/// Cross-file inputs for one registry layer's `registry` check.
pub struct RegistryCheck<'a> {
    /// Registry directory, repo-relative (e.g. `rust/src/protocols`).
    pub dir: &'a str,
    /// Module file stems found on disk, `mod.rs` excluded.
    pub module_files: &'a [String],
    /// The directory's `mod.rs`.
    pub mod_src: &'a SourceFile,
    /// Live registry names (from the compiled crate's REGISTRY).
    pub registered: &'a [&'a str],
    /// DESIGN.md text.
    pub design_text: &'a str,
    /// Layer label for messages (`protocol` / `objective` / `compressor` / `kernel`).
    pub layer: &'a str,
}

/// Rule `registry`: every module under a registry directory is wired
/// into its `REGISTRY` initializer, and every registered name is
/// documented in DESIGN.md. (`anytime-sgd list` renders the same
/// REGISTRY statics, so registration implies enumeration; the driver
/// separately checks `main.rs` still references each static.)
pub fn registry(check: &RegistryCheck<'_>) -> Vec<Finding> {
    let mut out = Vec::new();
    let (reg_line, reg_text) = registry_span(check.mod_src);
    for stem in check.module_files {
        let needle = format!("{stem}::");
        if !reg_text.contains(&needle) {
            out.push(Finding {
                rule: "registry",
                file: format!("{}/mod.rs", check.dir),
                line: reg_line,
                msg: format!(
                    "{layer} module `{stem}` ({dir}/{stem}.rs) is not wired into REGISTRY",
                    layer = check.layer,
                    dir = check.dir,
                ),
            });
        }
    }
    for name in check.registered {
        if !text_has_word(check.design_text, name) {
            out.push(Finding {
                rule: "registry",
                file: "DESIGN.md".to_string(),
                line: 1,
                msg: format!(
                    "registered {layer} `{name}` is not named anywhere in DESIGN.md",
                    layer = check.layer,
                ),
            });
        }
    }
    out
}

/// The REGISTRY initializer's (line, text): from the `static REGISTRY`
/// line through the first `;`.
fn registry_span(src: &SourceFile) -> (usize, String) {
    for (idx, code) in src.code.iter().enumerate() {
        if find_token(code, "REGISTRY").is_empty() || find_token(code, "static").is_empty() {
            continue;
        }
        let mut text = String::new();
        for line in src.code.iter().skip(idx) {
            text.push_str(line);
            text.push('\n');
            if line.contains(';') {
                break;
            }
        }
        return (idx + 1, text);
    }
    (1, String::new())
}

/// Word-boundary containment in prose (registry names may appear as
/// `topk`, `` `topk` ``, or `topk,` — but `sync` must not match
/// `async`).
fn text_has_word(text: &str, word: &str) -> bool {
    text.lines().any(|l| !find_token(l, word).is_empty())
}

/// Rule `wire-fingerprint`: the marker-delimited wire surface must
/// hash to the pinned fingerprint, and the pinned version must equal
/// the source `PROTOCOL_VERSION`. `pin_text` is the contents of
/// `rust/wire.fingerprint` (`None` = file missing).
pub fn wire_fingerprint(src: &SourceFile, pin_text: Option<&str>) -> Vec<Finding> {
    let mut f = |line: usize, msg: String| Finding {
        rule: "wire-fingerprint",
        file: src.path.clone(),
        line,
        msg,
    };
    let Some(surface) = fingerprint::extract(src) else {
        return vec![f(
            1,
            format!(
                "wire-surface markers (`{}` / `{}`) missing — the frame format can drift unpinned",
                fingerprint::BEGIN_MARKER,
                fingerprint::END_MARKER,
            ),
        )];
    };
    let Some(version) = surface.version else {
        return vec![f(1, "PROTOCOL_VERSION not found inside the wire-surface region".into())];
    };
    let Some(pin_text) = pin_text else {
        return vec![f(
            1,
            "fingerprint pin file missing — run `anytime-sgd lint --write-fingerprint`".into(),
        )];
    };
    let pin: Pin = match fingerprint::parse_pin(pin_text) {
        Ok(p) => p,
        Err(e) => return vec![f(1, format!("fingerprint pin file unreadable: {e}"))],
    };
    let mut out = Vec::new();
    if pin.fingerprint != surface.fingerprint {
        out.push(f(
            1,
            format!(
                "wire surface changed: fingerprint 0x{:016x} != pinned 0x{:016x} — bump \
                 PROTOCOL_VERSION and re-pin with `anytime-sgd lint --write-fingerprint` \
                 (DESIGN.md §10)",
                surface.fingerprint, pin.fingerprint,
            ),
        ));
    }
    if pin.version != version {
        out.push(f(
            1,
            format!(
                "pinned wire version {} != source PROTOCOL_VERSION {} — re-pin with \
                 `anytime-sgd lint --write-fingerprint`",
                pin.version, version,
            ),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn det_time_respects_allowlist_and_tests() {
        let text = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(det_time(&SourceFile::from_text("rust/src/theory/x.rs", text)).len(), 1);
        assert!(det_time(&SourceFile::from_text("rust/src/net/x.rs", text)).is_empty());
        let test_only = "#[cfg(test)]\nmod tests {\n fn f() { let t = Instant::now(); }\n}\n";
        assert!(det_time(&SourceFile::from_text("rust/src/theory/x.rs", test_only)).is_empty());
        // Doc/prose mentions never fire: scrubbed before scanning.
        let doc = "// uses Instant::now for deadlines\nfn g() {}\n";
        assert!(det_time(&SourceFile::from_text("rust/src/theory/x.rs", doc)).is_empty());
    }

    #[test]
    fn hostile_panic_fn_scope_is_precise() {
        let text = concat!(
            "pub fn decode(b: &[u8]) -> u8 {\n",
            "    b[0]\n",
            "}\n",
            "pub fn encode(v: &[u8]) -> u8 {\n",
            "    v[0] // encode side: out of rule scope\n",
            "}\n",
        );
        let src = SourceFile::from_text("x.rs", text);
        let found = hostile_panic(&src, PanicScope::Fns(&["decode"]));
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found.first().map(|f| f.line), Some(2));
    }

    #[test]
    fn index_detector_skips_literals_and_attributes() {
        assert!(index_sites("let v = vec![0u8; n];").is_empty());
        assert!(index_sites("#[derive(Debug)]").is_empty());
        assert!(index_sites("let a: [u8; 4] = x;").is_empty());
        assert!(index_sites("fn f() -> [f32; 3] {").is_empty());
        // Slice types after a lifetime: the ident before `[` is the
        // lifetime's name, not an indexed value.
        assert!(index_sites("buf: &'a [u8],").is_empty());
        assert!(index_sites("fn take(&self) -> Result<&'a [u8], E> {").is_empty());
        assert!(index_sites("const T: &'static [u8] = b\"x\";").is_empty());
        assert_eq!(index_sites("let x = buf[i];").len(), 1);
        assert_eq!(index_sites("m[k][j] = 0;").len(), 2);
        assert_eq!(index_sites("f(a)[0]").len(), 1);
    }

    #[test]
    fn debug_assert_is_allowed() {
        let text = "pub fn decode(b: &[u8]) { debug_assert!(!b.is_empty()); }\n";
        let src = SourceFile::from_text("x.rs", text);
        assert!(hostile_panic(&src, PanicScope::WholeFile).is_empty());
    }

    #[test]
    fn word_boundaries_in_design_lookup() {
        assert!(text_has_word("the `sync` baseline", "sync"));
        assert!(!text_has_word("the async baseline", "sync"));
        assert!(text_has_word("q8/q16 quantization", "q8"));
    }
}
