//! Wire-surface fingerprinting (rule `wire-fingerprint`).
//!
//! `net/wire.rs` brackets its frame-format surface — the protocol
//! version, the frame cap, the message structs/enum, and the tag
//! bytes — between two marker comments. This module extracts that
//! region, normalizes it (comments blanked, whitespace collapsed, so
//! doc edits never move the hash), and FNV-1a-64 hashes it. The hash
//! is pinned in `rust/wire.fingerprint` next to the protocol version;
//! the rule fails whenever the surface changes without *both* a
//! `PROTOCOL_VERSION` bump and a re-pin
//! (`anytime-sgd lint --write-fingerprint`) — the wire-discipline
//! contract of DESIGN.md §10.

use super::source::SourceFile;

/// Marker comment opening the fingerprinted region of `net/wire.rs`.
pub const BEGIN_MARKER: &str = "=== WIRE SURFACE";
/// Marker comment closing the region.
pub const END_MARKER: &str = "=== END WIRE SURFACE";

/// The extracted, normalized wire surface.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireSurface {
    /// Normalized region text (one collapsed line per source line).
    pub normalized: String,
    /// FNV-1a 64-bit hash of `normalized`.
    pub fingerprint: u64,
    /// `PROTOCOL_VERSION` parsed out of the region, if present.
    pub version: Option<u32>,
}

/// Extract the marker-delimited surface from a wire source file.
/// `None` when either marker is missing.
pub fn extract(src: &SourceFile) -> Option<WireSurface> {
    let begin = src.raw.iter().position(|l| l.contains(BEGIN_MARKER))?;
    let end = src.raw.iter().position(|l| l.contains(END_MARKER))?;
    if end <= begin {
        return None;
    }
    // Normalize from the *scrubbed* lines: comments are already
    // blanked, so pure-comment lines vanish and trailing doc text
    // never reaches the hash.
    let mut lines: Vec<String> = Vec::new();
    for code in src.code.iter().take(end).skip(begin + 1) {
        let collapsed = code.split_whitespace().collect::<Vec<_>>().join(" ");
        if !collapsed.is_empty() {
            lines.push(collapsed);
        }
    }
    let normalized = lines.join("\n");
    let fingerprint = fnv1a64(normalized.as_bytes());
    let version = parse_version(&normalized);
    Some(WireSurface { normalized, fingerprint, version })
}

/// FNV-1a, 64-bit.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn parse_version(normalized: &str) -> Option<u32> {
    let at = normalized.find("PROTOCOL_VERSION")?;
    let rest = &normalized[at..];
    let eq = rest.find('=')?;
    let tail = rest.get(eq + 1..)?;
    let num: String = tail.trim_start().chars().take_while(|c| c.is_ascii_digit()).collect();
    num.parse().ok()
}

/// The pinned (version, fingerprint) pair from `rust/wire.fingerprint`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pin {
    pub version: u32,
    pub fingerprint: u64,
}

/// Parse a pin file (`#` comments, `version = N`,
/// `fingerprint = 0x…`).
pub fn parse_pin(text: &str) -> Result<Pin, String> {
    let mut version: Option<u32> = None;
    let mut fingerprint: Option<u64> = None;
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(format!("pin file line {}: expected `key = value`", idx + 1));
        };
        let value = value.trim();
        match key.trim() {
            "version" => {
                version = Some(
                    value
                        .parse()
                        .map_err(|_| format!("pin file line {}: bad version", idx + 1))?,
                )
            }
            "fingerprint" => {
                let hex = value.strip_prefix("0x").unwrap_or(value);
                fingerprint = Some(
                    u64::from_str_radix(hex, 16)
                        .map_err(|_| format!("pin file line {}: bad fingerprint", idx + 1))?,
                )
            }
            other => return Err(format!("pin file line {}: unknown key `{other}`", idx + 1)),
        }
    }
    match (version, fingerprint) {
        (Some(version), Some(fingerprint)) => Ok(Pin { version, fingerprint }),
        _ => Err("pin file must set both `version` and `fingerprint`".to_string()),
    }
}

/// Render the pin file contents for `lint --write-fingerprint`.
pub fn render_pin(version: u32, fingerprint: u64) -> String {
    format!(
        "# Pinned fingerprint of the net/wire.rs message-enum surface\n\
         # (the marker-delimited region; see DESIGN.md §10).\n\
         #\n\
         # Any change to the wire surface must bump PROTOCOL_VERSION in\n\
         # rust/src/net/wire.rs and re-pin with:\n\
         #\n\
         #   cargo run --release -- lint --write-fingerprint\n\
         #\n\
         version = {version}\n\
         fingerprint = 0x{fingerprint:016x}\n"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_wire(field: &str) -> String {
        format!(
            "use x;\n\
             // === WIRE SURFACE (fingerprinted) ===\n\
             pub const PROTOCOL_VERSION: u32 = 3;\n\
             pub struct Frame {{\n\
                 /// doc text that must not move the hash\n\
                 pub {field}: u32,\n\
             }}\n\
             // === END WIRE SURFACE ===\n\
             fn after() {{}}\n"
        )
    }

    #[test]
    fn comment_and_whitespace_churn_keeps_the_hash() {
        let a = extract(&SourceFile::from_text("w.rs", &mini_wire("round"))).unwrap();
        let noisy = mini_wire("round")
            .replace("doc text that must not move the hash", "totally different words")
            .replace("pub round: u32,", "pub   round :  u32 , // inline note");
        let b = extract(&SourceFile::from_text("w.rs", &noisy)).unwrap();
        assert_eq!(a.fingerprint, b.fingerprint, "\n{}\nvs\n{}", a.normalized, b.normalized);
        assert_eq!(a.version, Some(3));
    }

    #[test]
    fn surface_changes_move_the_hash() {
        let a = extract(&SourceFile::from_text("w.rs", &mini_wire("round"))).unwrap();
        let b = extract(&SourceFile::from_text("w.rs", &mini_wire("epoch"))).unwrap();
        assert_ne!(a.fingerprint, b.fingerprint);
    }

    #[test]
    fn missing_markers_yield_none() {
        assert!(extract(&SourceFile::from_text("w.rs", "pub fn f() {}\n")).is_none());
    }

    #[test]
    fn pin_round_trips() {
        let text = render_pin(3, 0xDEAD_BEEF_0123_4567);
        let pin = parse_pin(&text).unwrap();
        assert_eq!(pin, Pin { version: 3, fingerprint: 0xDEAD_BEEF_0123_4567 });
        assert!(parse_pin("version = 3\n").is_err());
        assert!(parse_pin("version = 3\nfingerprint = xyz\n").is_err());
        assert!(parse_pin("nonsense\n").is_err());
    }

    #[test]
    fn fnv_vector() {
        // Standard FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
