//! Waiver file parsing for the static-analysis pass.
//!
//! Format (a deliberately tiny TOML subset, std-parsed — see
//! DESIGN.md §10):
//!
//! ```toml
//! [[waiver]]
//! rule = "det-time"
//! path = "rust/src/foo.rs"
//! line = 12                       # optional: whole file if omitted
//! justification = "why this specific site is sound"
//! ```
//!
//! Every entry must name a known rule, a repo-relative path, and a
//! non-empty justification — an unexplained waiver is a parse error,
//! not a finding. Waivers that match nothing produce a
//! `waiver-unused` finding (stale waivers rot into blanket excuses).

use super::rules::{Finding, RULES};

/// One parsed waiver entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Waiver {
    pub rule: String,
    pub path: String,
    pub line: Option<usize>,
    pub justification: String,
    /// Line of the `[[waiver]]` header (for `waiver-unused` findings).
    pub decl_line: usize,
}

impl Waiver {
    /// Whether this waiver covers the finding.
    pub fn covers(&self, f: &Finding) -> bool {
        self.rule == f.rule && self.path == f.file && self.line.map_or(true, |l| l == f.line)
    }
}

/// Parse a waiver file. Errors carry the offending line number.
pub fn parse(text: &str) -> Result<Vec<Waiver>, String> {
    let mut out: Vec<Waiver> = Vec::new();
    let mut cur: Option<Waiver> = None;
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[waiver]]" {
            if let Some(w) = cur.take() {
                validate(&w)?;
                out.push(w);
            }
            cur = Some(Waiver {
                rule: String::new(),
                path: String::new(),
                line: None,
                justification: String::new(),
                decl_line: lineno,
            });
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(format!("waiver file line {lineno}: expected `key = value`"));
        };
        let entry = cur.as_mut().ok_or_else(|| {
            format!("waiver file line {lineno}: `{}` before any [[waiver]]", key.trim())
        })?;
        let key = key.trim();
        let value = strip_comment(value).trim().to_string();
        match key {
            "rule" => entry.rule = unquote(&value, lineno)?,
            "path" => entry.path = unquote(&value, lineno)?,
            "justification" => entry.justification = unquote(&value, lineno)?,
            "line" => {
                entry.line = Some(value.parse::<usize>().map_err(|_| {
                    format!("waiver file line {lineno}: `line` must be an integer, got `{value}`")
                })?)
            }
            other => return Err(format!("waiver file line {lineno}: unknown key `{other}`")),
        }
    }
    if let Some(w) = cur.take() {
        validate(&w)?;
        out.push(w);
    }
    Ok(out)
}

/// Cut a trailing `# comment` — but only outside a quoted value, so a
/// justification may mention `#123` issue numbers.
fn strip_comment(value: &str) -> &str {
    let mut in_str = false;
    for (i, b) in value.bytes().enumerate() {
        match b {
            b'"' => in_str = !in_str,
            b'#' if !in_str => return &value[..i],
            _ => {}
        }
    }
    value
}

fn unquote(value: &str, lineno: usize) -> Result<String, String> {
    let inner = value
        .strip_prefix('"')
        .and_then(|v| v.strip_suffix('"'))
        .ok_or_else(|| format!("waiver file line {lineno}: expected a double-quoted string"))?;
    if inner.contains('"') || inner.contains('\\') {
        return Err(format!("waiver file line {lineno}: quotes/escapes not supported in values"));
    }
    Ok(inner.to_string())
}

fn validate(w: &Waiver) -> Result<(), String> {
    let at = w.decl_line;
    if w.rule.is_empty() {
        return Err(format!("waiver at line {at}: missing `rule`"));
    }
    if !RULES.iter().any(|r| r.id == w.rule) {
        let known: Vec<&str> = RULES.iter().map(|r| r.id).collect();
        return Err(format!(
            "waiver at line {at}: unknown rule `{}` (known: {})",
            w.rule,
            known.join(", ")
        ));
    }
    if w.path.is_empty() {
        return Err(format!("waiver at line {at}: missing `path`"));
    }
    if w.justification.trim().is_empty() {
        return Err(format!("waiver at line {at}: a non-empty `justification` is required"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_and_line_scoped_waivers() {
        let text = concat!(
            "# header comment\n",
            "\n",
            "[[waiver]]\n",
            "rule = \"det-time\"\n",
            "path = \"rust/src/foo.rs\"\n",
            "justification = \"benchmark scaffolding, not a result path\"\n",
            "\n",
            "[[waiver]]\n",
            "rule = \"det-order\"\n",
            "path = \"rust/src/bar.rs\"\n",
            "line = 42  # pinned to one site\n",
            "justification = \"keys are sorted two lines above\"\n",
        );
        let ws = parse(text).unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].rule, "det-time");
        assert_eq!(ws[0].line, None);
        assert_eq!(ws[1].line, Some(42));
        assert_eq!(ws[1].decl_line, 8);
        let f = Finding {
            rule: "det-order",
            file: "rust/src/bar.rs".into(),
            line: 42,
            msg: String::new(),
        };
        assert!(ws[1].covers(&f));
        assert!(!ws[0].covers(&f));
        let off = Finding { line: 43, ..f };
        assert!(!ws[1].covers(&off));
    }

    #[test]
    fn missing_justification_is_a_parse_error() {
        let text = "[[waiver]]\nrule = \"det-time\"\npath = \"x.rs\"\n";
        let err = parse(text).unwrap_err();
        assert!(err.contains("justification"), "{err}");
        let text = "[[waiver]]\nrule = \"det-time\"\npath = \"x.rs\"\njustification = \"\"\n";
        assert!(parse(text).is_err());
    }

    #[test]
    fn unknown_rules_keys_and_shapes_rejected() {
        assert!(parse("[[waiver]]\nrule = \"nope\"\npath = \"x\"\njustification = \"y\"\n")
            .unwrap_err()
            .contains("unknown rule"));
        assert!(parse("[[waiver]]\nseverity = \"low\"\n").unwrap_err().contains("unknown key"));
        assert!(parse("rule = \"det-time\"\n").unwrap_err().contains("before any"));
        assert!(parse("[[waiver]]\nrule = det-time\n").unwrap_err().contains("double-quoted"));
        assert!(parse("[[waiver]]\nline = \"ten\"\nrule = \"det-time\"\n").is_err());
    }

    #[test]
    fn empty_file_is_zero_waivers() {
        assert_eq!(parse("# no waivers\n").unwrap(), Vec::new());
        assert_eq!(parse("").unwrap(), Vec::new());
    }
}
