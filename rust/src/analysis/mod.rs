//! `analysis` — the in-tree static-analysis pass (DESIGN.md §10).
//!
//! Every bit-exactness pin the repo ships — sim ≡ real ≡ dist,
//! obs-on ≡ obs-off, identity-compressor dist ≡ sim — rests on
//! invariants a compiler never checks: no wall-clock or ambient-RNG
//! reads in the numeric core, no randomized-order map iteration in
//! result paths, no panics on hostile wire bytes, and a
//! `PROTOCOL_VERSION` bump whenever the frame format moves. This
//! module mechanizes those contracts as a std-only lint pass over the
//! repo's own source, exposed two ways:
//!
//! * `anytime-sgd lint` — the CLI gate (machine-readable findings,
//!   `--write-fingerprint` re-pins the wire surface);
//! * `rust/tests/analysis_gate.rs` — a tier-1 test, so plain
//!   `cargo test` fails on any violation.
//!
//! Rules live in [`rules`], the comment/string-aware source model in
//! [`source`], the waiver format in [`waivers`], and the
//! wire-surface hash in [`fingerprint`]. Findings can be waived only
//! through `rust/analysis_waivers.toml`, each with a written
//! justification; the tree ships with **zero** waivers.

pub mod fingerprint;
pub mod rules;
pub mod source;
pub mod waivers;

pub use rules::{Finding, PanicScope, RuleInfo, RULES};

use anyhow::{anyhow, bail, Context, Result};
use rules::RegistryCheck;
use source::SourceFile;
use std::path::{Path, PathBuf};
use waivers::Waiver;

/// Repo-relative path of the waiver file.
pub const WAIVER_FILE: &str = "rust/analysis_waivers.toml";
/// Repo-relative path of the wire-fingerprint pin.
pub const PIN_FILE: &str = "rust/wire.fingerprint";
/// Repo-relative path of the wire source.
pub const WIRE_FILE: &str = "rust/src/net/wire.rs";

/// Result of one full pass.
pub struct Outcome {
    /// Unwaived findings, sorted by (file, line, rule). Empty = clean.
    pub findings: Vec<Finding>,
    /// Waived findings with their justifications.
    pub waived: Vec<(Finding, String)>,
    /// Number of source files scanned.
    pub files_scanned: usize,
}

/// Locate the repo root: walk up from `cwd` looking for the crate's
/// `Cargo.toml` + `rust/src/lib.rs`, falling back to the compile-time
/// manifest dir (always right for in-tree `cargo run`/`cargo test`).
pub fn find_repo_root() -> Result<PathBuf> {
    if let Ok(mut dir) = std::env::current_dir() {
        loop {
            if dir.join("Cargo.toml").is_file() && dir.join("rust/src/lib.rs").is_file() {
                return Ok(dir);
            }
            if !dir.pop() {
                break;
            }
        }
    }
    let fallback = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    if fallback.join("rust/src/lib.rs").is_file() {
        return Ok(fallback);
    }
    bail!("cannot locate the repo root (no Cargo.toml + rust/src/lib.rs above the cwd)")
}

/// Run the full pass over the tree at `root`.
pub fn run(root: &Path) -> Result<Outcome> {
    let files = collect_sources(root)?;
    let mut findings: Vec<Finding> = Vec::new();
    let sources: Vec<SourceFile> = files
        .iter()
        .map(|rel| {
            SourceFile::load(&root.join(rel), rel)
                .with_context(|| format!("reading {rel}"))
        })
        .collect::<Result<_>>()?;

    for src in &sources {
        findings.extend(rules::det_time(src));
        findings.extend(rules::det_order(src));
        if let Some(scope) = panic_scope(&src.path) {
            findings.extend(rules::hostile_panic(src, scope));
        }
    }

    findings.extend(registry_findings(root, &sources)?);

    let wire = sources
        .iter()
        .find(|s| s.path == WIRE_FILE)
        .ok_or_else(|| anyhow!("{WIRE_FILE} not found under {}", root.display()))?;
    let pin_text = read_optional(&root.join(PIN_FILE))?;
    findings.extend(rules::wire_fingerprint(wire, pin_text.as_deref()));

    let waiver_text = read_optional(&root.join(WAIVER_FILE))?;
    let waiver_list = match waiver_text.as_deref() {
        Some(text) => waivers::parse(text).map_err(|e| anyhow!("{WAIVER_FILE}: {e}"))?,
        None => Vec::new(),
    };
    let (mut findings, waived, unused) = apply_waivers(findings, &waiver_list);
    for w in unused {
        findings.push(Finding {
            rule: "waiver-unused",
            file: WAIVER_FILE.to_string(),
            line: w.decl_line,
            msg: format!(
                "waiver for `{}` at {}{} matches no current finding — delete it",
                w.rule,
                w.path,
                w.line.map(|l| format!(":{l}")).unwrap_or_default(),
            ),
        });
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(Outcome { findings, waived, files_scanned: sources.len() })
}

/// Split findings into (unwaived, waived) against a waiver list, and
/// return the waivers that covered nothing. Exposed for the fixture
/// self-tests.
pub fn apply_waivers(
    findings: Vec<Finding>,
    waiver_list: &[Waiver],
) -> (Vec<Finding>, Vec<(Finding, String)>, Vec<Waiver>) {
    let mut used = vec![false; waiver_list.len()];
    let mut keep = Vec::new();
    let mut waived = Vec::new();
    for f in findings {
        match waiver_list.iter().position(|w| w.covers(&f)) {
            Some(i) => {
                used[i] = true;
                waived.push((f, waiver_list[i].justification.clone()));
            }
            None => keep.push(f),
        }
    }
    let unused = waiver_list
        .iter()
        .zip(used)
        .filter_map(|(w, u)| (!u).then(|| w.clone()))
        .collect();
    (keep, waived, unused)
}

/// The `hostile-panic` scope for a source path, if any (the issue's
/// rule 2 surface: the wire decoder, both `ser` codecs end to end,
/// and every compressor's decoder).
pub fn panic_scope(path: &str) -> Option<PanicScope<'static>> {
    match path {
        "rust/src/ser/parse.rs" | "rust/src/ser/bytes.rs" => Some(PanicScope::WholeFile),
        "rust/src/net/wire.rs" => Some(PanicScope::Fns(&["decode", "read_frame"])),
        p if p.starts_with("rust/src/compress/") => Some(PanicScope::Fns(&["decode"])),
        _ => None,
    }
}

fn registry_findings(root: &Path, sources: &[SourceFile]) -> Result<Vec<Finding>> {
    let design_text = std::fs::read_to_string(root.join("DESIGN.md"))
        .with_context(|| "reading DESIGN.md")?;
    let mut out = Vec::new();
    let layers: [(&str, Vec<&str>, &str); 3] = [
        (
            "rust/src/protocols",
            crate::protocols::REGISTRY.iter().map(|i| i.name).collect(),
            "protocol",
        ),
        (
            "rust/src/objective",
            crate::objective::REGISTRY.iter().map(|i| i.name).collect(),
            "objective",
        ),
        (
            "rust/src/compress",
            crate::compress::REGISTRY.iter().map(|i| i.name).collect(),
            "compressor",
        ),
    ];
    for (dir, registered, layer) in &layers {
        let module_files: Vec<String> = sources
            .iter()
            .filter_map(|s| {
                let rest = s.path.strip_prefix(&format!("{dir}/"))?;
                let stem = rest.strip_suffix(".rs")?;
                (!rest.contains('/') && stem != "mod").then(|| stem.to_string())
            })
            .collect();
        let mod_src = sources
            .iter()
            .find(|s| s.path == format!("{dir}/mod.rs"))
            .ok_or_else(|| anyhow!("{dir}/mod.rs not found"))?;
        out.extend(rules::registry(&RegistryCheck {
            dir,
            module_files: &module_files,
            mod_src,
            registered,
            design_text: &design_text,
            layer,
        }));
    }
    // The kernel registry lives in a single file (`linalg/kernels.rs`)
    // rather than a module-per-entry directory, so there is no wiring
    // check — but every registered kernel set must still be named in
    // DESIGN.md, same as the other plug-in layers.
    let kernels_src = sources
        .iter()
        .find(|s| s.path == "rust/src/linalg/kernels.rs")
        .ok_or_else(|| anyhow!("rust/src/linalg/kernels.rs not found"))?;
    let kernel_names: Vec<&str> =
        crate::linalg::kernels::REGISTRY.iter().map(|i| i.name).collect();
    out.extend(rules::registry(&RegistryCheck {
        dir: "rust/src/linalg",
        module_files: &[],
        mod_src: kernels_src,
        registered: &kernel_names,
        design_text: &design_text,
        layer: "kernel",
    }));
    // `anytime-sgd list` renders these REGISTRY statics directly;
    // losing a reference would silently drop a layer from enumeration.
    if let Some(main) = sources.iter().find(|s| s.path == "rust/src/main.rs") {
        for reg in [
            "protocols::REGISTRY",
            "objective::REGISTRY",
            "compress::REGISTRY",
            "linalg::kernels::REGISTRY",
        ] {
            let hit = main
                .code
                .iter()
                .any(|l| !source::find_token(l, reg).is_empty());
            if !hit {
                out.push(Finding {
                    rule: "registry",
                    file: main.path.clone(),
                    line: 1,
                    msg: format!("`anytime-sgd list` no longer renders {reg}"),
                });
            }
        }
    }
    Ok(out)
}

/// Every `.rs` file under `rust/src`, repo-relative with forward
/// slashes, sorted (deterministic findings order).
fn collect_sources(root: &Path) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let base = root.join("rust/src");
    walk(&base, &mut out)?;
    let mut rel: Vec<String> = out
        .iter()
        .filter_map(|p| {
            p.strip_prefix(root)
                .ok()
                .map(|r| r.components().map(|c| c.as_os_str().to_string_lossy()).collect::<Vec<_>>().join("/"))
        })
        .collect();
    rel.sort();
    Ok(rel)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Read a file that may legitimately be absent.
fn read_optional(path: &Path) -> Result<Option<String>> {
    match std::fs::read_to_string(path) {
        Ok(text) => Ok(Some(text)),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
        Err(e) => Err(e).with_context(|| format!("reading {}", path.display())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panic_scopes_cover_the_issue_surface() {
        assert!(matches!(panic_scope("rust/src/ser/parse.rs"), Some(PanicScope::WholeFile)));
        assert!(matches!(panic_scope("rust/src/ser/bytes.rs"), Some(PanicScope::WholeFile)));
        assert!(matches!(panic_scope("rust/src/net/wire.rs"), Some(PanicScope::Fns(_))));
        assert!(matches!(panic_scope("rust/src/compress/topk.rs"), Some(PanicScope::Fns(_))));
        assert!(panic_scope("rust/src/figures/mod.rs").is_none());
    }

    #[test]
    fn apply_waivers_partitions_and_reports_stale() {
        let f = |line| Finding {
            rule: "det-time",
            file: "rust/src/x.rs".to_string(),
            line,
            msg: String::new(),
        };
        let ws = waivers::parse(concat!(
            "[[waiver]]\n",
            "rule = \"det-time\"\n",
            "path = \"rust/src/x.rs\"\n",
            "line = 2\n",
            "justification = \"fixture\"\n",
            "[[waiver]]\n",
            "rule = \"det-time\"\n",
            "path = \"rust/src/never.rs\"\n",
            "justification = \"stale\"\n",
        ))
        .unwrap();
        let (keep, waived, unused) = apply_waivers(vec![f(1), f(2)], &ws);
        assert_eq!(keep.len(), 1);
        assert_eq!(keep.first().map(|k| k.line), Some(1));
        assert_eq!(waived.len(), 1);
        assert_eq!(unused.len(), 1);
        assert_eq!(unused.first().map(|u| u.path.as_str()), Some("rust/src/never.rs"));
    }
}
