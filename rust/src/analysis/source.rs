//! Source model for the in-tree static-analysis pass (DESIGN.md §10).
//!
//! Loads one Rust source file and produces a per-line *scrubbed* view:
//! comment bodies and string/char-literal bodies are blanked to spaces
//! (newlines preserved, so findings keep real line numbers), and lines
//! inside `#[cfg(test)]` items are marked so rules can skip test code.
//!
//! The scrubber is a lexer-grade scanner, not a parser: it understands
//! line comments, nested block comments, string and raw-string
//! literals (`r"…"`, `r#"…"#`), byte strings/chars, and char literals
//! vs lifetimes — enough to make naive token scans sound on real
//! source. Anything it blanks can never produce a finding, so a rule
//! token appearing in a doc comment or an error-message string is
//! never a false positive.

use std::path::Path;

/// One parsed source file: raw lines, scrubbed lines, test-span marks.
pub struct SourceFile {
    /// Repo-relative path, forward slashes (the path findings report).
    pub path: String,
    /// Raw line text, index `i` = line `i + 1`.
    pub raw: Vec<String>,
    /// Scrubbed line text, same shape as `raw`.
    pub code: Vec<String>,
    /// Whether line `i + 1` sits inside a `#[cfg(test)]` item.
    pub in_test: Vec<bool>,
}

impl SourceFile {
    /// Load and scrub a file from disk. `rel` is the repo-relative
    /// path used in findings.
    pub fn load(abs: &Path, rel: &str) -> std::io::Result<SourceFile> {
        let text = std::fs::read_to_string(abs)?;
        Ok(Self::from_text(rel, &text))
    }

    /// Build the model from in-memory text (fixture self-tests).
    pub fn from_text(rel: &str, text: &str) -> SourceFile {
        let scrubbed = scrub(text);
        let raw: Vec<String> = text.lines().map(str::to_string).collect();
        let code: Vec<String> = scrubbed.lines().map(str::to_string).collect();
        let in_test = mark_test_spans(&code);
        SourceFile { path: rel.to_string(), raw, code, in_test }
    }

    /// Number of lines.
    pub fn len(&self) -> usize {
        self.raw.len()
    }

    pub fn is_empty(&self) -> bool {
        self.raw.is_empty()
    }

    /// Line spans (1-based, inclusive) of every non-test `fn <name>`
    /// body. Bodiless declarations (trait methods ending in `;`) are
    /// skipped — there is nothing in them to scan.
    pub fn fn_spans(&self, name: &str) -> Vec<(usize, usize)> {
        let mut spans = Vec::new();
        let needle = format!("fn {name}");
        for start in 0..self.code.len() {
            if self.in_test.get(start).copied().unwrap_or(false) {
                continue;
            }
            for col in find_token(&self.code[start], &needle) {
                // `fn name` must be followed by `(` or `<`, not a
                // longer identifier (word-boundary on the name).
                let after = self.code[start][col + needle.len()..].trim_start();
                if !(after.starts_with('(') || after.starts_with('<')) {
                    continue;
                }
                if let Some(end) = self.match_braces_from(start, col) {
                    spans.push((start + 1, end + 1));
                }
            }
        }
        spans
    }

    /// From (line, col), scan forward for the first `{` or `;` at
    /// nesting depth zero; on `{`, return the line index of its
    /// matching `}`. `None` for bodiless declarations.
    fn match_braces_from(&self, line: usize, col: usize) -> Option<usize> {
        let mut depth = 0usize;
        let mut seen_open = false;
        let mut li = line;
        let mut ci = col;
        while li < self.code.len() {
            let bytes = self.code[li].as_bytes();
            while ci < bytes.len() {
                match bytes[ci] {
                    b';' if !seen_open => return None,
                    b'{' => {
                        seen_open = true;
                        depth += 1;
                    }
                    b'}' if seen_open => {
                        depth -= 1;
                        if depth == 0 {
                            return Some(li);
                        }
                    }
                    _ => {}
                }
                ci += 1;
            }
            li += 1;
            ci = 0;
        }
        None
    }
}

/// Occurrences of `token` in `line` with word boundaries: if the
/// token's first (last) char is an identifier char, the preceding
/// (following) char must not be one. Tokens starting with `.` or
/// ending with `(`/`)`/`!` therefore match exactly as written.
pub fn find_token(line: &str, token: &str) -> Vec<usize> {
    let lb = line.as_bytes();
    let tb = token.as_bytes();
    let first_ident = tb.first().is_some_and(|&b| is_ident(b));
    let last_ident = tb.last().is_some_and(|&b| is_ident(b));
    let mut hits = Vec::new();
    if tb.is_empty() || lb.len() < tb.len() {
        return hits;
    }
    for i in 0..=lb.len() - tb.len() {
        if &lb[i..i + tb.len()] != tb {
            continue;
        }
        if first_ident && i > 0 && is_ident(lb[i - 1]) {
            continue;
        }
        if last_ident && lb.get(i + tb.len()).copied().is_some_and(is_ident) {
            continue;
        }
        hits.push(i);
    }
    hits
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Blank comment bodies and string/char-literal bodies to spaces,
/// preserving newlines and everything else.
pub fn scrub(text: &str) -> String {
    let src: Vec<char> = text.chars().collect();
    let mut out = String::with_capacity(text.len());
    let mut i = 0usize;
    let blank = |out: &mut String, src: &[char], from: usize, to: usize| {
        for &c in src.iter().take(to).skip(from) {
            out.push(if c == '\n' { '\n' } else { ' ' });
        }
    };
    while i < src.len() {
        let c = src[i];
        let next = src.get(i + 1).copied();
        // Line comment (incl. /// and //! doc comments).
        if c == '/' && next == Some('/') {
            let mut j = i;
            while j < src.len() && src[j] != '\n' {
                j += 1;
            }
            blank(&mut out, &src, i, j);
            i = j;
            continue;
        }
        // Block comment, nesting honored.
        if c == '/' && next == Some('*') {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < src.len() && depth > 0 {
                if src[j] == '/' && src.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 2;
                } else if src[j] == '*' && src.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, &src, i, j);
            i = j;
            continue;
        }
        // Raw (byte) string: r"…", r#"…"#, br#"…"# — no escapes, the
        // closing quote must carry the same number of `#`s.
        let raw_start = match (c, next) {
            ('r', _) => Some(i + 1),
            ('b', Some('r')) => Some(i + 2),
            _ => None,
        };
        if let Some(mut j) = raw_start {
            let prev_ident = i > 0 && (src[i - 1].is_ascii_alphanumeric() || src[i - 1] == '_');
            let mut hashes = 0usize;
            while src.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if !prev_ident && src.get(j) == Some(&'"') {
                j += 1;
                'scan: while j < src.len() {
                    if src[j] == '"' {
                        let mut k = 0usize;
                        while k < hashes && src.get(j + 1 + k) == Some(&'#') {
                            k += 1;
                        }
                        if k == hashes {
                            j += 1 + hashes;
                            break 'scan;
                        }
                    }
                    j += 1;
                }
                blank(&mut out, &src, i, j);
                i = j;
                continue;
            }
        }
        // Byte string b"…" falls through to the string arm below.
        if c == 'b' && next == Some('"') {
            out.push(' ');
            i += 1;
            continue;
        }
        // String literal with escapes.
        if c == '"' {
            let mut j = i + 1;
            while j < src.len() {
                if src[j] == '\\' {
                    j += 2;
                } else if src[j] == '"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, &src, i, j.min(src.len()));
            i = j.min(src.len());
            continue;
        }
        // Char literal vs lifetime. `'x'`, `'\n'`, `b'{'` are
        // literals; `'a` in `<'a>` or `'outer:` is a lifetime and is
        // kept as-is.
        if c == '\'' {
            let is_escape = next == Some('\\');
            let closes = src.get(i + 2) == Some(&'\'');
            if is_escape || (next.is_some() && closes) {
                // Escaped literal: '\?' (2-char escapes cover every
                // escape the repo uses); simple literal: 'x'.
                let end = if is_escape {
                    let mut j = i + 2;
                    while j < src.len() && src[j] != '\'' {
                        j += 1;
                    }
                    (j + 1).min(src.len())
                } else {
                    i + 3
                };
                blank(&mut out, &src, i, end);
                i = end;
                continue;
            }
            out.push(c);
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

/// Mark every line belonging to a `#[cfg(test)]` item: the attribute
/// line through the matching `}` of the item's body (or its `;` for a
/// bodiless item).
fn mark_test_spans(code: &[String]) -> Vec<bool> {
    let mut marks = vec![false; code.len()];
    for start in 0..code.len() {
        if find_token(&code[start], "#[cfg(test)]").is_empty() {
            continue;
        }
        // Scan forward from the end of the attribute for the item's
        // body braces (or a terminating `;`).
        let mut depth = 0usize;
        let mut seen_open = false;
        let mut end = start;
        'outer: for (off, line) in code.iter().enumerate().skip(start) {
            let from = if off == start {
                find_token(line, "#[cfg(test)]")
                    .first()
                    .map(|c| c + "#[cfg(test)]".len())
                    .unwrap_or(0)
            } else {
                0
            };
            for b in line.as_bytes().iter().skip(from) {
                match b {
                    b';' if !seen_open => {
                        end = off;
                        break 'outer;
                    }
                    b'{' => {
                        seen_open = true;
                        depth += 1;
                    }
                    b'}' if seen_open => {
                        depth -= 1;
                        if depth == 0 {
                            end = off;
                            break 'outer;
                        }
                    }
                    _ => {}
                }
            }
            end = off;
        }
        for m in marks.iter_mut().take(end + 1).skip(start) {
            *m = true;
        }
    }
    marks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrub_blanks_comments_and_strings() {
        let text = "let x = 1; // Instant::now\nlet s = \"HashMap\"; /* SystemTime */ let y = 2;\n";
        let got = scrub(text);
        assert!(!got.contains("Instant"), "{got}");
        assert!(!got.contains("HashMap"), "{got}");
        assert!(!got.contains("SystemTime"), "{got}");
        assert!(got.contains("let x = 1;"));
        assert!(got.contains("let y = 2;"));
        // Line structure preserved.
        assert_eq!(got.lines().count(), text.lines().count());
    }

    #[test]
    fn scrub_handles_raw_strings_chars_and_lifetimes() {
        let text = concat!(
            "let r1 = r#\"unwrap() \"quoted\" body\"#;\n",
            "let c = '\\n'; let b = b'{'; fn f<'a>(x: &'a str) {}\n",
            "let nested = \"say \\\"unwrap()\\\" twice\";\n",
        );
        let got = scrub(text);
        assert!(!got.contains("unwrap"), "{got}");
        assert!(!got.contains("quoted"), "{got}");
        // The lifetime survives; the brace balance is untouched by the
        // blanked b'{' literal.
        assert!(got.contains("<'a>"), "{got}");
        let opens = got.matches('{').count();
        let closes = got.matches('}').count();
        assert_eq!(opens, closes, "{got}");
    }

    #[test]
    fn test_spans_are_marked() {
        let text = concat!(
            "pub fn live() {}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn helper() { let _ = 1; }\n",
            "}\n",
            "pub fn also_live() {}\n",
        );
        let sf = SourceFile::from_text("x.rs", text);
        assert_eq!(sf.in_test, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn fn_spans_find_bodies_and_skip_declarations() {
        let text = concat!(
            "trait T {\n",
            "    fn decode(&self) -> u8;\n",
            "}\n",
            "impl T for () {\n",
            "    fn decode(&self) -> u8 {\n",
            "        0\n",
            "    }\n",
            "}\n",
            "fn decoder() {}\n",
        );
        let sf = SourceFile::from_text("x.rs", text);
        assert_eq!(sf.fn_spans("decode"), vec![(5, 7)]);
        // `decoder` has a word boundary after `decode`, so it is a
        // different token entirely.
        assert_eq!(sf.fn_spans("decoder"), vec![(9, 9)]);
    }

    #[test]
    fn token_boundaries() {
        assert_eq!(find_token("Instantiate(x)", "Instant").len(), 0);
        assert_eq!(find_token("Instant::now()", "Instant::now").len(), 1);
        assert_eq!(find_token("debug_assert!(x)", "assert!").len(), 0);
        assert_eq!(find_token("assert!(x)", "assert!").len(), 1);
        assert_eq!(find_token("x.unwrap_or(0)", ".unwrap()").len(), 0);
        assert_eq!(find_token("x.unwrap()", ".unwrap()").len(), 1);
    }
}
