//! `compress` — pluggable gradient/iterate compression for the dist
//! wire.
//!
//! The anytime scheme's premise is that every worker's partial work
//! reaches the master in time; on real links the raw-bit f32 payloads
//! of [`crate::net::wire`] make the *wire* the straggler. This module
//! is the fourth plug-in axis (protocol × runtime × objective ×
//! **compressor**): a [`Compressor`] trait behind a name-keyed
//! [`REGISTRY`] mirroring [`crate::protocols`] and [`crate::objective`],
//! negotiated per connection during the `Hello`/`Assign` handshake
//! (wire v3) and applied by [`crate::net::master`] /
//! [`crate::net::worker`] to every `Task.x0` and `Report.x_k`/`x_bar`
//! payload — so `NetEpochStats` and the obs `RunReport` count
//! *compressed* frame bytes.
//!
//! ## Codec layer vs stream layer
//!
//! A [`Compressor`] is a pure, stateless quantizer: `encode` turns a
//! vector into a compact payload, `decode` reconstructs a `dim`-length
//! vector from hostile bytes (error, never panic). Convergence-grade
//! transport needs more than per-message quantization, though: lossy
//! codecs are applied to the *delta* against a receiver-mirroring
//! state, with an error-feedback residual, by [`StreamEncoder`] /
//! [`StreamDecoder`]:
//!
//! ```text
//! sender (per stream)                    receiver (per stream)
//!   u      = v − mirror + residual
//!   bytes  = codec.encode(u)      ──►    d̂ = codec.decode(bytes)
//!   d̂      = codec.decode(bytes)         mirror += d̂
//!   residual = u − d̂                     yield mirror
//!   mirror  += d̂
//! ```
//!
//! Both ends apply the identical f32 update sequence, so the mirrors
//! stay in bit-exact lockstep; the residual re-injects whatever the
//! codec dropped into the next message (error feedback, à la
//! 1-bit/EF-SGD), so the receiver's mirror tracks the true vector and
//! the quantization error stays bounded instead of accumulating.
//! `identity` is flagged lossless and bypasses the delta/residual
//! machinery entirely — its payloads are the raw IEEE-754 bits, so the
//! dist ≡ sim bit-exactness pins survive unchanged.
//!
//! Empty vectors (a `Busy` task's `x0`, an idle report) travel as empty
//! payloads and never touch stream state.
//!
//! ## Wire formats (payload layouts, all little-endian)
//!
//! | name       | layout                                   | bytes (dim d) |
//! |------------|------------------------------------------|---------------|
//! | `identity` | d × f32 raw bits                         | `4d`          |
//! | `topk`     | u32 k, then k × (u32 idx, f32 val), idx strictly ascending | `4 + 8k`, k = max(1, d/16) |
//! | `signsgd`  | f64 scale (mean \|v\|), then ⌈d/8⌉ sign-bit bytes (pad bits zero) | `8 + ⌈d/8⌉` |
//! | `q8`       | f32 lo, f32 hi, then d × u8 levels       | `8 + d`       |
//! | `q16`      | f32 lo, f32 hi, then d × u16 levels      | `8 + 2d`      |
//!
//! Lossy codecs are defined for finite inputs; non-finite coordinates
//! are tolerated without panicking (they contribute nothing to
//! `signsgd`'s scale and clamp to `q8`/`q16`'s range), and hostile
//! payloads — k > d, out-of-range or non-ascending indices, non-finite
//! scale/range headers, wrong lengths — always decode to an error.
//!
//! ## Adding a compressor (~40 LoC)
//!
//! 1. `rust/src/compress/mycodec.rs`: a unit struct implementing
//!    [`Compressor`] (`spec`/`encode`/`decode`) plus a
//!    `pub const INFO: CompressorInfo` with its name, aliases, one-line
//!    `about`, `lossless` flag, and `build` hook.
//! 2. Add a variant to [`CompressorSpec`] and arms to `name()` and
//!    `parse()`; give it the next wire kind byte in `wire_kind()` /
//!    `from_wire_kind()` (and bump [`MAX_WIRE_KIND`]).
//! 3. Register it: `mod mycodec;` here and `&mycodec::INFO` in
//!    [`REGISTRY`].
//!
//! That's it — config JSON, `train --compressor`, the sweep
//! `compressors` axis, `anytime-sgd list`, and the wire negotiation all
//! resolve through the registry.

pub mod identity;
pub mod quant;
pub mod signsgd;
pub mod topk;

use crate::ser::Value;
use anyhow::{anyhow, bail, Result};

/// A pure vector quantizer (see module docs): `encode` is total,
/// `decode` treats its input as hostile and errors instead of
/// panicking.
pub trait Compressor: Send {
    /// The spec this codec was built from.
    fn spec(&self) -> CompressorSpec;

    /// Quantize `v` into a payload. Must return an empty payload for an
    /// empty input.
    fn encode(&self, v: &[f32]) -> Vec<u8>;

    /// Reconstruct a `dim`-length vector from a payload. Hostile bytes
    /// (wrong length, corrupt headers, bad index streams) error, never
    /// panic.
    fn decode(&self, bytes: &[u8], dim: usize) -> Result<Vec<f32>>;
}

/// Registry entry: identity and lookup metadata for one codec.
pub struct CompressorInfo {
    /// Canonical registry key (CLI/JSON/wire negotiation name).
    pub name: &'static str,
    /// Accepted alternate names.
    pub aliases: &'static [&'static str],
    /// One-line description for `anytime-sgd list`.
    pub about: &'static str,
    /// Bit-exact passthrough: the stream layer skips the delta/
    /// error-feedback machinery and ships raw payloads.
    pub lossless: bool,
    /// Construct the codec.
    pub build: fn() -> Box<dyn Compressor>,
}

/// Every registered compressor. Order is display order for
/// `anytime-sgd list`.
pub static REGISTRY: &[&CompressorInfo] =
    &[&identity::INFO, &topk::INFO, &signsgd::INFO, &quant::INFO_Q8, &quant::INFO_Q16];

/// Resolve a codec name (canonical or alias) to its registry entry.
pub fn lookup(name: &str) -> Result<&'static CompressorInfo> {
    REGISTRY
        .iter()
        .find(|i| i.name == name || i.aliases.contains(&name))
        .copied()
        .ok_or_else(|| anyhow!("unknown compressor `{name}` (available: {})", names().join(", ")))
}

/// Registry entry for a spec (infallible: every variant is registered).
pub fn info(spec: CompressorSpec) -> &'static CompressorInfo {
    REGISTRY
        .iter()
        .find(|i| i.name == spec.name())
        .copied()
        .unwrap_or_else(|| unreachable!("unregistered compressor spec {spec:?}"))
}

/// Canonical names, registry order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|i| i.name).collect()
}

/// Whether `name` resolves (canonical or alias).
pub fn exists(name: &str) -> bool {
    lookup(name).is_ok()
}

/// Highest valid wire kind byte — the shared bound between
/// [`CompressorSpec::from_wire_kind`] and the `Assign` frame decoder,
/// so a locally-valid config can never be rejected only at the worker.
pub const MAX_WIRE_KIND: u8 = 4;

/// Which codec a run ships its `Task`/`Report` vector payloads through.
/// `Identity` everywhere except the dist runtime is a no-op: the
/// compressor is a wire concept, and the sim/real runtimes have no
/// wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompressorSpec {
    /// Raw f32 bits; bit-exact (the dist ≡ sim pins run through this).
    Identity,
    /// Top-k magnitude sparsification, k = max(1, d/16).
    TopK,
    /// 1-bit sign + f64 scale with error feedback (EF-signSGD).
    SignSgd,
    /// Linear 8-bit quantization with a min/max header.
    Q8,
    /// Linear 16-bit quantization with a min/max header.
    Q16,
}

impl CompressorSpec {
    /// Canonical registry name.
    pub fn name(self) -> &'static str {
        match self {
            CompressorSpec::Identity => "identity",
            CompressorSpec::TopK => "topk",
            CompressorSpec::SignSgd => "signsgd",
            CompressorSpec::Q8 => "q8",
            CompressorSpec::Q16 => "q16",
        }
    }

    /// Parse a CLI/JSON name (canonical or alias) through the registry.
    pub fn parse(name: &str) -> Result<Self> {
        let info = lookup(name)?;
        Ok(match info.name {
            "identity" => CompressorSpec::Identity,
            "topk" => CompressorSpec::TopK,
            "signsgd" => CompressorSpec::SignSgd,
            "q8" => CompressorSpec::Q8,
            "q16" => CompressorSpec::Q16,
            other => unreachable!("registry entry `{other}` has no spec arm"),
        })
    }

    /// From a config JSON value: a bare name string (`"topk"`) or an
    /// object with a `kind` field (`{"kind": "topk"}`).
    pub fn from_json(v: &Value) -> Result<Self> {
        if let Some(name) = v.as_str() {
            return Self::parse(name);
        }
        if v.as_obj().is_some() {
            let kind = v
                .get_str("kind")
                .ok_or_else(|| anyhow!("compressor object needs a `kind` name"))?;
            return Self::parse(kind);
        }
        bail!("compressor must be a name string or an object with `kind`")
    }

    /// Config JSON form (the canonical name).
    pub fn to_json(self) -> Value {
        Value::Str(self.name().to_string())
    }

    /// Config-level validation hook (kept for symmetry with the other
    /// spec enums; no compressor currently carries parameters).
    pub fn validate(self) -> Result<()> {
        Ok(())
    }

    /// Wire kind byte for the `Assign` frame (bounded by
    /// [`MAX_WIRE_KIND`]).
    pub fn wire_kind(self) -> u8 {
        match self {
            CompressorSpec::Identity => 0,
            CompressorSpec::TopK => 1,
            CompressorSpec::SignSgd => 2,
            CompressorSpec::Q8 => 3,
            CompressorSpec::Q16 => 4,
        }
    }

    /// Decode a wire kind byte (`None` = out of domain; the frame
    /// decoder maps that to a `BadValue`).
    pub fn from_wire_kind(kind: u8) -> Option<Self> {
        match kind {
            0 => Some(CompressorSpec::Identity),
            1 => Some(CompressorSpec::TopK),
            2 => Some(CompressorSpec::SignSgd),
            3 => Some(CompressorSpec::Q8),
            4 => Some(CompressorSpec::Q16),
            _ => None,
        }
    }

    /// Whether the codec is a bit-exact passthrough.
    pub fn lossless(self) -> bool {
        info(self).lossless
    }

    /// Build the codec.
    pub fn build(self) -> Box<dyn Compressor> {
        (info(self).build)()
    }
}

/// Sender half of one compressed vector stream (see module docs):
/// per-stream delta-vs-mirror encoding with an error-feedback residual
/// for lossy codecs, raw passthrough for lossless ones.
pub struct StreamEncoder {
    codec: Box<dyn Compressor>,
    lossless: bool,
    mirror: Vec<f32>,
    residual: Vec<f32>,
}

impl StreamEncoder {
    pub fn new(spec: CompressorSpec) -> Self {
        Self {
            codec: spec.build(),
            lossless: spec.lossless(),
            mirror: Vec::new(),
            residual: Vec::new(),
        }
    }

    /// Encode the next vector of the stream. Empty vectors yield empty
    /// payloads and leave the stream state untouched.
    pub fn encode(&mut self, v: &[f32]) -> Vec<u8> {
        if v.is_empty() {
            return Vec::new();
        }
        if self.lossless {
            return self.codec.encode(v);
        }
        if self.mirror.len() != v.len() {
            self.mirror = vec![0.0; v.len()];
            self.residual = vec![0.0; v.len()];
        }
        let u: Vec<f32> = v
            .iter()
            .zip(self.mirror.iter().zip(self.residual.iter()))
            .map(|(&x, (&m, &r))| x - m + r)
            .collect();
        let payload = self.codec.encode(&u);
        // Replay the receiver's reconstruction so both mirrors apply
        // the identical f32 update sequence (bit-exact lockstep). Our
        // own payload always decodes: a failure here is a codec bug,
        // not hostile input.
        let dec = self
            .codec
            .decode(&payload, v.len())
            .expect("codec must decode its own payload");
        for i in 0..v.len() {
            self.residual[i] = u[i] - dec[i];
            self.mirror[i] += dec[i];
        }
        payload
    }

    /// The codec spec this stream runs.
    pub fn spec(&self) -> CompressorSpec {
        self.codec.spec()
    }
}

/// Receiver half of one compressed vector stream: integrates decoded
/// deltas into a mirror of the sender's vector. Must see every payload
/// of the stream in send order.
pub struct StreamDecoder {
    codec: Box<dyn Compressor>,
    lossless: bool,
    mirror: Vec<f32>,
}

impl StreamDecoder {
    pub fn new(spec: CompressorSpec) -> Self {
        Self { codec: spec.build(), lossless: spec.lossless(), mirror: Vec::new() }
    }

    /// Decode the next payload of the stream into a `dim`-length
    /// vector. Empty payloads decode to empty vectors and leave the
    /// stream state untouched; hostile payloads error, never panic.
    pub fn decode(&mut self, bytes: &[u8], dim: usize) -> Result<Vec<f32>> {
        if bytes.is_empty() {
            return Ok(Vec::new());
        }
        if self.lossless {
            return self.codec.decode(bytes, dim);
        }
        let dec = self.codec.decode(bytes, dim)?;
        if self.mirror.len() != dim {
            self.mirror = vec![0.0; dim];
        }
        // `dec` is exactly `dim` long (codec decode contract); the zip
        // keeps this hostile-fed path free of raw indexing.
        for (m, d) in self.mirror.iter_mut().zip(dec.iter()) {
            *m += *d;
        }
        Ok(self.mirror.clone())
    }

    /// The codec spec this stream runs.
    pub fn spec(&self) -> CompressorSpec {
        self.codec.spec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    const ALL: [CompressorSpec; 5] = [
        CompressorSpec::Identity,
        CompressorSpec::TopK,
        CompressorSpec::SignSgd,
        CompressorSpec::Q8,
        CompressorSpec::Q16,
    ];

    #[test]
    fn registry_names_unique_and_resolvable() {
        let names = names();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), names.len(), "duplicate registry names");
        for info in REGISTRY {
            assert!(exists(info.name));
            assert!(!info.about.is_empty());
            for alias in info.aliases {
                assert_eq!(lookup(alias).unwrap().name, info.name, "alias {alias}");
                assert!(!names.contains(alias), "alias {alias} shadows a canonical name");
            }
            let built = (info.build)();
            assert_eq!(built.spec().name(), info.name);
        }
        assert!(lookup("gzip").unwrap_err().to_string().contains("available"));
    }

    #[test]
    fn specs_parse_round_trip_json_and_wire_kinds() {
        for spec in ALL {
            assert_eq!(CompressorSpec::parse(spec.name()).unwrap(), spec);
            assert_eq!(CompressorSpec::from_json(&spec.to_json()).unwrap(), spec);
            let obj = Value::obj(vec![("kind", spec.to_json())]);
            assert_eq!(CompressorSpec::from_json(&obj).unwrap(), spec);
            assert_eq!(CompressorSpec::from_wire_kind(spec.wire_kind()), Some(spec));
            assert!(spec.wire_kind() <= MAX_WIRE_KIND);
            spec.validate().unwrap();
            assert_eq!(spec.build().spec(), spec);
        }
        // Aliases resolve; junk fails closed.
        assert_eq!(CompressorSpec::parse("sign").unwrap(), CompressorSpec::SignSgd);
        assert_eq!(CompressorSpec::parse("none").unwrap(), CompressorSpec::Identity);
        assert!(CompressorSpec::parse("gzip").is_err());
        assert!(CompressorSpec::from_json(&Value::Num(3.0)).is_err());
        assert!(CompressorSpec::from_json(&Value::obj(vec![("k", Value::Num(2.0))])).is_err());
        assert_eq!(CompressorSpec::from_wire_kind(MAX_WIRE_KIND + 1), None);
        assert_eq!(CompressorSpec::from_wire_kind(0xFF), None);
        // Only identity is lossless.
        assert!(CompressorSpec::Identity.lossless());
        for spec in [
            CompressorSpec::TopK,
            CompressorSpec::SignSgd,
            CompressorSpec::Q8,
            CompressorSpec::Q16,
        ] {
            assert!(!spec.lossless(), "{spec:?}");
        }
    }

    /// Fuzz-style vector sampler covering the awkward floats (mirrors
    /// `net::wire`'s fuzzers).
    fn fuzz_vec(rng: &mut Xoshiro256pp, max_len: usize) -> Vec<f32> {
        let n = rng.index(max_len + 1);
        (0..n)
            .map(|_| match rng.index(6) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => f32::NEG_INFINITY,
                3 => 0.0,
                4 => -0.0,
                _ => (rng.next_f64() * 2e3 - 1e3) as f32,
            })
            .collect()
    }

    #[test]
    fn every_codec_fuzzes_without_panicking() {
        let mut rng = Xoshiro256pp::seed_from_u64(0xC0DEC);
        for spec in ALL {
            let codec = spec.build();
            // encode is total (NaN/±inf included) and decode(encode(v))
            // yields the right shape.
            for _ in 0..200 {
                let v = fuzz_vec(&mut rng, 48);
                let payload = codec.encode(&v);
                if v.is_empty() {
                    assert!(payload.is_empty(), "{spec:?}: empty in, empty out");
                    continue;
                }
                let back = codec.decode(&payload, v.len()).unwrap();
                assert_eq!(back.len(), v.len(), "{spec:?}");
                // Wrong dims must error, never panic.
                assert!(codec.decode(&payload, v.len() + 1).is_err(), "{spec:?}");
            }
            // Random garbage payloads: Ok or Err, never a panic.
            for _ in 0..300 {
                let n = rng.index(96);
                let junk: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
                let _ = codec.decode(&junk, rng.index(33));
            }
            // Bit-flips on well-formed payloads.
            for _ in 0..100 {
                let v: Vec<f32> = (0..17).map(|i| (i as f32) - 8.0).collect();
                let mut payload = codec.encode(&v);
                let i = rng.index(payload.len());
                payload[i] ^= 1 << rng.index(8);
                let _ = codec.decode(&payload, v.len());
            }
        }
    }

    #[test]
    fn identity_is_bit_exact_including_specials() {
        let codec = CompressorSpec::Identity.build();
        let v = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 0.0, 1.5e-30, -7.25];
        let payload = codec.encode(&v);
        assert_eq!(payload.len(), 4 * v.len());
        let back = codec.decode(&payload, v.len()).unwrap();
        for (a, b) in v.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // And through the stream layer: lossless = raw passthrough.
        let mut enc = StreamEncoder::new(CompressorSpec::Identity);
        let mut dec = StreamDecoder::new(CompressorSpec::Identity);
        for _ in 0..3 {
            let payload = enc.encode(&v);
            assert_eq!(payload, codec.encode(&v));
            let back = dec.decode(&payload, v.len()).unwrap();
            for (a, b) in v.iter().zip(back.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn lossy_codecs_stay_within_documented_bounds() {
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let v: Vec<f32> = (0..64).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();

        // topk: selected coordinates exact, the rest zero.
        let codec = CompressorSpec::TopK.build();
        let back = codec.decode(&codec.encode(&v), v.len()).unwrap();
        let mut kept = 0;
        for (a, b) in v.iter().zip(back.iter()) {
            if *b != 0.0 {
                assert_eq!(a.to_bits(), b.to_bits(), "kept coordinate must be exact");
                kept += 1;
            }
        }
        assert_eq!(kept, 64 / 16, "k = max(1, d/16)");
        // The kept ones are the largest magnitudes.
        let mut mags: Vec<f32> = v.iter().map(|x| x.abs()).collect();
        mags.sort_by(f32::total_cmp);
        let cut = mags[mags.len() - kept];
        for (a, b) in v.iter().zip(back.iter()) {
            if a.abs() > cut {
                assert_ne!(*b, 0.0, "large coordinate {a} dropped");
            }
        }

        // signsgd: every coordinate is ±scale, scale = mean |v|.
        let codec = CompressorSpec::SignSgd.build();
        let back = codec.decode(&codec.encode(&v), v.len()).unwrap();
        let scale = v.iter().map(|x| x.abs() as f64).sum::<f64>() / v.len() as f64;
        for (a, b) in v.iter().zip(back.iter()) {
            assert!((b.abs() as f64 - scale).abs() < 1e-6, "|{b}| != scale {scale}");
            assert_eq!(a.is_sign_positive(), b.is_sign_positive());
        }

        // q8/q16: per-coordinate error within one quantization level.
        for (spec, levels) in [(CompressorSpec::Q8, 255.0f64), (CompressorSpec::Q16, 65_535.0f64)] {
            let codec = spec.build();
            let back = codec.decode(&codec.encode(&v), v.len()).unwrap();
            let lo = v.iter().copied().fold(f32::INFINITY, f32::min) as f64;
            let hi = v.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
            let step = (hi - lo) / levels;
            for (a, b) in v.iter().zip(back.iter()) {
                assert!(
                    (*a as f64 - *b as f64).abs() <= step + 1e-6,
                    "{spec:?}: |{a} - {b}| > level {step}"
                );
            }
        }
    }

    #[test]
    fn stream_error_feedback_tracks_a_drifting_vector() {
        // A slowly-drifting vector (an SGD iterate's shape of motion),
        // then a hold phase: while drifting, the mirror error must stay
        // bounded (error feedback — dropped mass is re-sent, never
        // lost); once the vector stops moving, the residual flushes and
        // the mirror converges onto the true vector. For every lossy
        // codec.
        for spec in [
            CompressorSpec::TopK,
            CompressorSpec::SignSgd,
            CompressorSpec::Q8,
            CompressorSpec::Q16,
        ] {
            let mut rng = Xoshiro256pp::seed_from_u64(31);
            let d = 32;
            let mut enc = StreamEncoder::new(spec);
            let mut dec = StreamDecoder::new(spec);
            let mut v = vec![0.0f32; d];
            let err_of = |v: &[f32], got: &[f32]| -> f64 {
                v.iter().zip(got.iter()).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt()
            };
            let mut final_err = f64::INFINITY;
            let mut norm = 0.0f64;
            for round in 0..200 {
                if round < 100 {
                    for x in v.iter_mut() {
                        *x += (rng.next_f64() * 0.02 - 0.01) as f32;
                    }
                }
                let payload = enc.encode(&v);
                let got = dec.decode(&payload, d).unwrap();
                final_err = err_of(&v, &got);
                norm = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
                assert!(
                    final_err.is_finite() && final_err <= 2.0 * norm + 0.1,
                    "{spec:?} round {round}: mirror error {final_err} vs ‖v‖ {norm}"
                );
            }
            // 100 hold rounds flushed the residual: the receiver now
            // sits essentially on top of the sender.
            assert!(
                final_err <= (0.05 * norm).max(1e-3),
                "{spec:?}: residual failed to flush — error {final_err}, ‖v‖ {norm}"
            );
        }
    }

    #[test]
    fn streams_handle_empty_vectors_without_losing_state() {
        let mut enc = StreamEncoder::new(CompressorSpec::TopK);
        let mut dec = StreamDecoder::new(CompressorSpec::TopK);
        let v = vec![1.0f32, -2.0, 3.0, -4.0];
        let p1 = enc.encode(&v);
        let g1 = dec.decode(&p1, 4).unwrap();
        // An interleaved empty message (a Busy task / idle report).
        assert!(enc.encode(&[]).is_empty());
        assert_eq!(dec.decode(&[], 4).unwrap(), Vec::<f32>::new());
        // The stream resumes exactly where it left off.
        let p2 = enc.encode(&v);
        let g2 = dec.decode(&p2, 4).unwrap();
        assert_eq!(g1.len(), 4);
        assert_eq!(g2.len(), 4);
        // Second round's mirror is at least as close as the first.
        let err = |g: &[f32]| -> f64 {
            v.iter().zip(g.iter()).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
        };
        assert!(err(&g2) <= err(&g1) + 1e-12);
    }
}
