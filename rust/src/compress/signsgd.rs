//! `signsgd` — 1-bit sign compression with an f64 scale (EF-signSGD).
//!
//! Payload: an f64 scale (the mean absolute value of the vector,
//! computed over finite coordinates), then `⌈d/8⌉` bytes of sign bits
//! — bit `i % 8` of byte `i / 8` is set when coordinate `i` is
//! non-negative (IEEE sign bit clear). A coordinate decodes to
//! `±scale`, so a dense d-vector's `4d` bytes become `8 + ⌈d/8⌉` — a
//! ~32× reduction for large d. The 1-bit quantization error is what
//! the stream layer's error-feedback residual exists for: dropped
//! magnitude is re-sent on later messages (Seide et al.'s 1-bit SGD /
//! EF-signSGD construction).
//!
//! Decode rejects: wrong payload length, a non-finite or negative
//! scale, and set padding bits in the final byte.

use super::{Compressor, CompressorInfo, CompressorSpec};
use crate::ser::bytes::{ByteReader, ByteWriter};
use anyhow::{bail, Result};

pub struct SignSgd;

fn build() -> Box<dyn Compressor> {
    Box::new(SignSgd)
}

pub const INFO: CompressorInfo = CompressorInfo {
    name: "signsgd",
    aliases: &["sign", "1bit", "ef-signsgd"],
    about: "1-bit sign + f64 scale with error feedback (~32x for large d)",
    lossless: false,
    build,
};

impl Compressor for SignSgd {
    fn spec(&self) -> CompressorSpec {
        CompressorSpec::SignSgd
    }

    fn encode(&self, v: &[f32]) -> Vec<u8> {
        if v.is_empty() {
            return Vec::new();
        }
        // Scale over finite coordinates only, so a stray NaN/inf cannot
        // poison the whole message (the residual still carries it).
        let sum: f64 = v.iter().filter(|x| x.is_finite()).map(|x| x.abs() as f64).sum();
        let scale = sum / v.len() as f64;
        let mut w = ByteWriter::with_capacity(8 + v.len().div_ceil(8));
        w.put_f64(scale);
        let mut byte = 0u8;
        for (i, x) in v.iter().enumerate() {
            if x.is_sign_positive() {
                byte |= 1 << (i % 8);
            }
            if i % 8 == 7 {
                w.put_u8(byte);
                byte = 0;
            }
        }
        if v.len() % 8 != 0 {
            w.put_u8(byte);
        }
        w.into_bytes()
    }

    fn decode(&self, bytes: &[u8], dim: usize) -> Result<Vec<f32>> {
        if dim == 0 {
            if bytes.is_empty() {
                return Ok(Vec::new());
            }
            bail!("signsgd payload: {} bytes for dim 0", bytes.len());
        }
        let want = 8 + dim.div_ceil(8);
        if bytes.len() != want {
            bail!("signsgd payload: {} bytes for dim {dim} (want {want})", bytes.len());
        }
        let mut r = ByteReader::new(bytes);
        let scale = r.get_f64()?;
        if !scale.is_finite() || scale < 0.0 {
            bail!("signsgd payload: invalid scale {scale}");
        }
        let mut out = Vec::with_capacity(dim);
        let mut last = 0u8;
        for i in 0..dim {
            if i % 8 == 0 {
                last = r.get_u8()?;
            }
            let sign = if last & (1 << (i % 8)) != 0 { 1.0 } else { -1.0 };
            out.push((sign * scale) as f32);
        }
        // Padding bits beyond `dim` must be clear — a set one means the
        // sender disagrees about the dimension (or the bytes are junk).
        if dim % 8 != 0 && last >> (dim % 8) != 0 {
            bail!("signsgd payload: non-zero padding bits");
        }
        r.finish()?;
        Ok(out)
    }
}
