//! `identity` — bit-exact raw-bit passthrough.
//!
//! Payload: `dim` f32 values as little-endian IEEE-754 bit patterns,
//! nothing else — byte-for-byte what `put_f32s` shipped before the
//! compression axis existed, so frame sizes and values are unchanged
//! and every dist ≡ sim equivalence pin survives. This is the default
//! compressor and the registry's lossless reference point.

use super::{Compressor, CompressorInfo, CompressorSpec};
use crate::ser::bytes::ByteReader;
use anyhow::{anyhow, bail, Result};

pub struct Identity;

fn build() -> Box<dyn Compressor> {
    Box::new(Identity)
}

pub const INFO: CompressorInfo = CompressorInfo {
    name: "identity",
    aliases: &["id", "none", "raw"],
    about: "raw f32 bits, bit-exact (default; 4d bytes)",
    lossless: true,
    build,
};

impl Compressor for Identity {
    fn spec(&self) -> CompressorSpec {
        CompressorSpec::Identity
    }

    fn encode(&self, v: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 * v.len());
        for &x in v {
            out.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        out
    }

    fn decode(&self, bytes: &[u8], dim: usize) -> Result<Vec<f32>> {
        if bytes.len() != 4 * dim {
            bail!("identity payload: {} bytes for dim {dim} (want {})", bytes.len(), 4 * dim);
        }
        let mut r = ByteReader::new(bytes);
        let mut out = Vec::with_capacity(dim);
        for _ in 0..dim {
            out.push(r.get_f32().map_err(|e| anyhow!("identity payload: {e}"))?);
        }
        Ok(out)
    }
}
