//! `q8` / `q16` — linear quantization with a min/max header.
//!
//! Payload: f32 `lo`, f32 `hi` (the vector's finite min/max), then one
//! u8 (`q8`, 255 levels) or little-endian u16 (`q16`, 65535 levels)
//! level per coordinate: `level = round((x − lo) / (hi − lo) · L)`,
//! decoding to `lo + level/L · (hi − lo)`. A dense d-vector's `4d`
//! bytes become `8 + d` (~4×) or `8 + 2d` (~2×). Per-coordinate error
//! is at most half a level — `(hi − lo) / 2L` — and the stream layer's
//! error-feedback residual keeps even that from accumulating across
//! messages. Non-finite coordinates clamp to `lo` (NaN) or the nearest
//! bound (±inf) without panicking.
//!
//! Decode rejects wrong payload lengths and non-finite or inverted
//! (`lo > hi`) range headers.

use super::{Compressor, CompressorInfo, CompressorSpec};
use crate::ser::bytes::{ByteReader, ByteWriter};
use anyhow::{bail, Result};

/// Linear quantizer; `wide = false` is q8, `wide = true` is q16.
pub struct Quant {
    wide: bool,
}

fn build_q8() -> Box<dyn Compressor> {
    Box::new(Quant { wide: false })
}

fn build_q16() -> Box<dyn Compressor> {
    Box::new(Quant { wide: true })
}

pub const INFO_Q8: CompressorInfo = CompressorInfo {
    name: "q8",
    aliases: &["quant8", "u8"],
    about: "linear 8-bit quantization with min/max header (~4x)",
    lossless: false,
    build: build_q8,
};

pub const INFO_Q16: CompressorInfo = CompressorInfo {
    name: "q16",
    aliases: &["quant16", "u16"],
    about: "linear 16-bit quantization with min/max header (~2x)",
    lossless: false,
    build: build_q16,
};

impl Quant {
    fn levels(&self) -> f64 {
        if self.wide {
            65_535.0
        } else {
            255.0
        }
    }

    fn width(&self) -> usize {
        if self.wide {
            2
        } else {
            1
        }
    }
}

impl Compressor for Quant {
    fn spec(&self) -> CompressorSpec {
        if self.wide {
            CompressorSpec::Q16
        } else {
            CompressorSpec::Q8
        }
    }

    fn encode(&self, v: &[f32]) -> Vec<u8> {
        if v.is_empty() {
            return Vec::new();
        }
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in v {
            if x.is_finite() {
                lo = lo.min(x);
                hi = hi.max(x);
            }
        }
        if lo > hi {
            // No finite coordinate at all: a degenerate zero range.
            lo = 0.0;
            hi = 0.0;
        }
        let levels = self.levels();
        let span = (hi - lo) as f64;
        let mut w = ByteWriter::with_capacity(8 + self.width() * v.len());
        w.put_f32(lo);
        w.put_f32(hi);
        for &x in v {
            let xc = if x.is_finite() { x.clamp(lo, hi) } else if x == f32::INFINITY { hi } else { lo };
            let q = if span > 0.0 {
                (((xc - lo) as f64 / span) * levels).round().min(levels) as u32
            } else {
                0
            };
            if self.wide {
                w.put_u8(q as u8);
                w.put_u8((q >> 8) as u8);
            } else {
                w.put_u8(q as u8);
            }
        }
        w.into_bytes()
    }

    fn decode(&self, bytes: &[u8], dim: usize) -> Result<Vec<f32>> {
        let kind = if self.wide { "q16" } else { "q8" };
        if dim == 0 {
            if bytes.is_empty() {
                return Ok(Vec::new());
            }
            bail!("{kind} payload: {} bytes for dim 0", bytes.len());
        }
        let want = 8 + self.width() * dim;
        if bytes.len() != want {
            bail!("{kind} payload: {} bytes for dim {dim} (want {want})", bytes.len());
        }
        let mut r = ByteReader::new(bytes);
        let lo = r.get_f32()?;
        let hi = r.get_f32()?;
        if !lo.is_finite() || !hi.is_finite() || lo > hi {
            bail!("{kind} payload: invalid range [{lo}, {hi}]");
        }
        let levels = self.levels();
        let span = (hi - lo) as f64;
        let mut out = Vec::with_capacity(dim);
        for _ in 0..dim {
            let q = if self.wide {
                let a = r.get_u8()? as u32;
                let b = r.get_u8()? as u32;
                a | (b << 8)
            } else {
                r.get_u8()? as u32
            };
            out.push((lo as f64 + (q as f64 / levels) * span) as f32);
        }
        r.finish()?;
        Ok(out)
    }
}
