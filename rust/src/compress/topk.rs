//! `topk` — top-k magnitude sparsification.
//!
//! Payload: `u32 k`, then `k` (u32 index, f32 raw-bit value) pairs with
//! strictly ascending indices. k = max(1, d/16), so a dense d-vector's
//! `4d` bytes become `4 + 8·max(1, d/16)` ≈ `d/2` — an ~8× reduction
//! for large d. Kept coordinates travel exactly (raw bits); dropped
//! ones decode to zero and are re-injected by the stream layer's
//! error-feedback residual on the next message.
//!
//! Decode treats the payload as hostile: k > dim, an index out of
//! range, a non-ascending (duplicate) index stream, or a length that
//! disagrees with k all error, never panic.

use super::{Compressor, CompressorInfo, CompressorSpec};
use crate::ser::bytes::{ByteReader, ByteWriter};
use anyhow::{anyhow, bail, Result};

/// Sparsification denominator: k = max(1, d / DENOM).
pub const DENOM: usize = 16;

pub struct TopK;

fn build() -> Box<dyn Compressor> {
    Box::new(TopK)
}

pub const INFO: CompressorInfo = CompressorInfo {
    name: "topk",
    aliases: &["top-k", "sparse"],
    about: "top-k magnitude sparsification, k = max(1, d/16) (~8x for large d)",
    lossless: false,
    build,
};

/// k for a given dimension (0 for the empty vector).
pub fn k_for(dim: usize) -> usize {
    (dim / DENOM).max(1).min(dim)
}

impl Compressor for TopK {
    fn spec(&self) -> CompressorSpec {
        CompressorSpec::TopK
    }

    fn encode(&self, v: &[f32]) -> Vec<u8> {
        let k = k_for(v.len());
        if k == 0 {
            return Vec::new();
        }
        // Deterministic selection: magnitude descending (total order,
        // so NaN/±inf never panic a comparator), ties to the lower
        // index.
        let mut idx: Vec<u32> = (0..v.len() as u32).collect();
        idx.sort_by(|&i, &j| {
            v[j as usize]
                .abs()
                .total_cmp(&v[i as usize].abs())
                .then_with(|| i.cmp(&j))
        });
        idx.truncate(k);
        idx.sort_unstable();
        let mut w = ByteWriter::with_capacity(4 + 8 * k);
        w.put_u32(k as u32);
        for &i in &idx {
            w.put_u32(i);
            w.put_f32(v[i as usize]);
        }
        w.into_bytes()
    }

    fn decode(&self, bytes: &[u8], dim: usize) -> Result<Vec<f32>> {
        if dim == 0 {
            if bytes.is_empty() {
                return Ok(Vec::new());
            }
            bail!("topk payload: {} bytes for dim 0", bytes.len());
        }
        let mut r = ByteReader::new(bytes);
        let k = r.get_u32()? as usize;
        if k > dim {
            bail!("topk payload: k {k} exceeds dim {dim}");
        }
        if bytes.len() != 4 + 8 * k {
            bail!("topk payload: {} bytes for k {k} (want {})", bytes.len(), 4 + 8 * k);
        }
        let mut out = vec![0.0f32; dim];
        let mut prev: Option<u32> = None;
        for _ in 0..k {
            let i = r.get_u32()?;
            let x = r.get_f32()?;
            if prev.is_some_and(|p| i <= p) {
                bail!("topk payload: non-ascending index {i}");
            }
            prev = Some(i);
            // Checked write doubles as the range check (hostile index).
            let slot = out
                .get_mut(i as usize)
                .ok_or_else(|| anyhow!("topk payload: index {i} out of range for dim {dim}"))?;
            *slot = x;
        }
        r.finish()?;
        Ok(out)
    }
}
