//! Dense f32 linear algebra for the native backend and the metrics path.
//!
//! No BLAS is available in the image, so this is a small, cache-aware
//! substrate: a row-major [`Matrix`], `gemv`/`gemv_t`, dot/axpy/norms,
//! and the fused operations the native SGD hot loop needs. The kernels
//! accumulate in f64 where it matters for reproducibility of the error
//! metric (‖Ax − Ax*‖ over 5e5 rows is ill-conditioned in pure f32).

pub mod kernels;
mod solve;

pub use kernels::KernelSpec;
pub use solve::{lstsq, solve, solve_consistent};

/// Row-major dense f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Allocate a zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "Matrix::from_vec: size mismatch");
        Self { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row access.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// The backing row-major slice.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable backing slice.
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Element access (row, col) — for tests; hot paths use rows.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Copy a subset of rows into a new matrix (minibatch gather).
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Gather rows into a preallocated row-major buffer (no allocation).
    pub fn gather_rows_into(&self, idx: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), idx.len() * self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out[k * self.cols..(k + 1) * self.cols].copy_from_slice(self.row(i));
        }
    }
}

/// Dot product with f64 accumulation, 4-way unrolled.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] as f64 * b[i] as f64;
        s1 += a[i + 1] as f64 * b[i + 1] as f64;
        s2 += a[i + 2] as f64 * b[i + 2] as f64;
        s3 += a[i + 3] as f64 * b[i + 3] as f64;
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] as f64 * b[i] as f64;
    }
    s
}

/// Fast f32-accumulated dot for the SGD hot loop (residual computation).
/// 8-way unrolled; the minibatch residual tolerates f32 accumulation.
/// (Lane-width choice and the campaign's measurement protocol are
/// documented in EXPERIMENTS.md §Perf; wider multi-bank variants are
/// expected to lose to register pressure on 16-register x86-64.)
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Fused SGD update: apply a minibatch gradient in factored per-sample
/// form (see [`crate::objective::GradBuf`]) directly to the parameter —
/// `x[c·d..(c+1)·d] += scale · coeff[i·k + c] · A.row(rows[i])` for
/// every sample `i` and logit channel `c`. The d-vector gradient is
/// never materialized: gradient accumulation and the axpy update are
/// one pass over the minibatch rows, allocation-free.
///
/// For `classes == 1` the loop is the pre-refactor least-squares hot
/// loop float-op for float-op (per-sample axpys applied sequentially to
/// `x`), which is what keeps the golden traces bit-exact across the
/// objective refactor. Benched in `benches/bench_objective.rs`.
#[inline]
pub fn sgd_update(a: &Matrix, rows: &[u32], coeff: &[f32], classes: usize, scale: f32, x: &mut [f32]) {
    let d = a.cols();
    debug_assert!(classes >= 1);
    debug_assert_eq!(x.len(), classes * d);
    debug_assert_eq!(coeff.len(), rows.len() * classes);
    if classes == 1 {
        for (i, &r) in rows.iter().enumerate() {
            axpy(scale * coeff[i], a.row(r as usize), x);
        }
    } else {
        for (i, &r) in rows.iter().enumerate() {
            let row = a.row(r as usize);
            for c in 0..classes {
                axpy(scale * coeff[i * classes + c], row, &mut x[c * d..(c + 1) * d]);
            }
        }
    }
}

/// `y = A x` (row-major gemv). `y.len() == A.rows()`.
pub fn gemv(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), a.cols());
    assert_eq!(y.len(), a.rows());
    for i in 0..a.rows() {
        y[i] = dot_f32(a.row(i), x);
    }
}

/// `y = A^T r` for row-major A: accumulate `r[i] * A.row(i)` into y.
pub fn gemv_t(a: &Matrix, r: &[f32], y: &mut [f32]) {
    assert_eq!(r.len(), a.rows());
    assert_eq!(y.len(), a.cols());
    y.fill(0.0);
    for i in 0..a.rows() {
        axpy(r[i], a.row(i), y);
    }
}

/// ‖x‖₂ with f64 accumulation.
pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// ‖a − b‖₂ with f64 accumulation.
pub fn dist2(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = (*x - *y) as f64;
        s += d * d;
    }
    s.sqrt()
}

/// Weighted sum of rows: `out = Σ_v w[v] * xs[v]` — the master's combine.
///
/// Accumulates in f64 to keep combining exactly associative-independent
/// (the same result regardless of worker arrival order).
pub fn weighted_sum(xs: &[&[f32]], w: &[f64], out: &mut [f32]) {
    assert_eq!(xs.len(), w.len());
    let d = out.len();
    for x in xs {
        assert_eq!(x.len(), d, "weighted_sum: ragged inputs");
    }
    // Column-major accumulation order over a row chunk keeps all worker
    // vectors' chunks hot in cache. The accumulator lives on the stack
    // (32 KiB) so the per-epoch combine never allocates; the arithmetic
    // order is identical to the old heap scratch, so the
    // order-independence pin below is unaffected.
    const CHUNK: usize = 4096;
    let mut acc = [0.0f64; CHUNK];
    let mut start = 0;
    while start < d {
        let end = (start + CHUNK).min(d);
        let len = end - start;
        acc[..len].fill(0.0);
        for (x, &wv) in xs.iter().zip(w.iter()) {
            if wv == 0.0 {
                continue;
            }
            for (a, &xv) in acc[..len].iter_mut().zip(x[start..end].iter()) {
                *a += wv * xv as f64;
            }
        }
        for (o, &a) in out[start..end].iter_mut().zip(acc[..len].iter()) {
            *o = a as f32;
        }
        start = end;
    }
}

/// Blocked `C = A B` (row-major, f32 accumulation) — used by tests and
/// the MSD-like generator's low-rank mixing; not on the SGD hot path.
pub fn gemm(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols(), b.rows());
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.cols());
    c.as_mut_slice().fill(0.0);
    const BK: usize = 64;
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    for k0 in (0..k).step_by(BK) {
        let kmax = (k0 + BK).min(k);
        for i in 0..m {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            for kk in k0..kmax {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                axpy(aik, brow, crow);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn randn_matrix(r: usize, c: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut m = Matrix::zeros(r, c);
        rng.fill_normal_f32(m.as_mut_slice());
        m
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.1 - 5.0).collect();
        let b: Vec<f32> = (0..103).map(|i| (i as f32) * -0.03 + 1.0).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9);
        assert!((dot_f32(&a, &b) as f64 - naive).abs() < 1e-2);
    }

    #[test]
    fn gemv_matches_naive() {
        let a = randn_matrix(17, 31, 1);
        let x: Vec<f32> = (0..31).map(|i| (i as f32).sin()).collect();
        let mut y = vec![0.0f32; 17];
        gemv(&a, &x, &mut y);
        for i in 0..17 {
            let naive: f32 = a.row(i).iter().zip(&x).map(|(p, q)| p * q).sum();
            assert!((y[i] - naive).abs() < 1e-3, "row {i}: {} vs {naive}", y[i]);
        }
    }

    #[test]
    fn gemv_t_matches_naive() {
        let a = randn_matrix(9, 13, 2);
        let r: Vec<f32> = (0..9).map(|i| (i as f32) * 0.25 - 1.0).collect();
        let mut y = vec![0.0f32; 13];
        gemv_t(&a, &r, &mut y);
        for j in 0..13 {
            let naive: f32 = (0..9).map(|i| a.get(i, j) * r[i]).sum();
            assert!((y[j] - naive).abs() < 1e-4);
        }
    }

    #[test]
    fn gemm_matches_naive_small() {
        let a = randn_matrix(7, 11, 3);
        let b = randn_matrix(11, 5, 4);
        let mut c = Matrix::zeros(7, 5);
        gemm(&a, &b, &mut c);
        for i in 0..7 {
            for j in 0..5 {
                let naive: f32 = (0..11).map(|k| a.get(i, k) * b.get(k, j)).sum();
                assert!((c.get(i, j) - naive).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn weighted_sum_matches_naive_and_is_order_independent() {
        let d = 10_000;
        let xs: Vec<Vec<f32>> = (0..5).map(|v| {
            let mut rng = Xoshiro256pp::seed_from_u64(100 + v);
            let mut x = vec![0.0f32; d];
            rng.fill_normal_f32(&mut x);
            x
        }).collect();
        let w = [0.4, 0.0, 0.25, 0.2, 0.15];
        let refs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        let mut out = vec![0.0f32; d];
        weighted_sum(&refs, &w, &mut out);
        // Naive check at a few positions.
        for &j in &[0usize, 1, 4999, d - 1] {
            let naive: f64 = xs.iter().zip(&w).map(|(x, &wv)| wv * x[j] as f64).sum();
            assert!((out[j] as f64 - naive).abs() < 1e-5);
        }
        // Permuted order gives bit-identical output (f64 accumulation is
        // not associative in general, but we check the permutation the
        // coordinator actually performs: reordering *workers*).
        let perm = [2usize, 0, 4, 1, 3];
        let refs2: Vec<&[f32]> = perm.iter().map(|&i| xs[i].as_slice()).collect();
        let w2: Vec<f64> = perm.iter().map(|&i| w[i]).collect();
        let mut out2 = vec![0.0f32; d];
        weighted_sum(&refs2, &w2, &mut out2);
        for j in 0..d {
            assert!((out[j] - out2[j]).abs() < 1e-6);
        }
    }

    #[test]
    fn gather_rows_into_matches_gather_rows() {
        let a = randn_matrix(20, 6, 5);
        let idx = [3usize, 19, 0, 7];
        let g = a.gather_rows(&idx);
        let mut buf = vec![0.0f32; idx.len() * 6];
        a.gather_rows_into(&idx, &mut buf);
        assert_eq!(g.as_slice(), &buf[..]);
    }

    #[test]
    fn sgd_update_matches_sequential_axpys() {
        let a = randn_matrix(32, 6, 7);
        let rows = [3u32, 17, 0, 31];
        let coeff = [0.5f32, -1.25, 2.0, 0.125];
        let scale = -0.01f32;
        // classes = 1: must equal the historical per-row axpy loop bit
        // for bit (the golden-trace contract).
        let mut x = vec![0.3f32; 6];
        let mut want = x.clone();
        for (i, &r) in rows.iter().enumerate() {
            axpy(scale * coeff[i], a.row(r as usize), &mut want);
        }
        sgd_update(&a, &rows, &coeff, 1, scale, &mut x);
        assert_eq!(x, want);

        // classes = 3: each class slice gets its own coefficient.
        let k = 3;
        let coeff3: Vec<f32> = (0..rows.len() * k).map(|i| i as f32 * 0.1 - 0.5).collect();
        let mut x3 = vec![0.2f32; 6 * k];
        let mut want3 = x3.clone();
        for (i, &r) in rows.iter().enumerate() {
            for c in 0..k {
                axpy(scale * coeff3[i * k + c], a.row(r as usize), &mut want3[c * 6..(c + 1) * 6]);
            }
        }
        sgd_update(&a, &rows, &coeff3, k, scale, &mut x3);
        assert_eq!(x3, want3);
    }

    #[test]
    fn norms() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert!((dist2(&[1.0, 1.0], &[4.0, 5.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn from_vec_size_mismatch_panics() {
        Matrix::from_vec(2, 3, vec![0.0; 5]);
    }
}
