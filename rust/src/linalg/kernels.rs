//! `kernels` — the kernel-dispatch layer: another registry-keyed
//! plug-in axis (protocol × objective × compressor × **kernels**)
//! selecting which float-op sequence the numeric core runs.
//!
//! Two kernel sets ship:
//!
//! * `reference` — the default: today's float-op-for-float-op paths
//!   ([`super::dot_f32`], [`super::axpy`], [`super::sgd_update`], the
//!   per-class logit loop). Every bit-exactness pin the repo carries —
//!   golden traces, sim ≡ real ≡ dist, obs-on ≡ obs-off — runs through
//!   this set, which is why it stays the default.
//! * `fast` — the raw-speed set (ROADMAP item 3): FMA + 8-lane
//!   multi-accumulator [`dot_f32_fast`]/[`axpy_fast`]/[`dot_fast`] with
//!   `mul_add` and explicit chunking for autovectorization, a fused
//!   multi-class [`sgd_update_fast`] that reads each minibatch row once
//!   per cache-blocked column tile and updates all k class-major slices
//!   while the tile is hot in L1 (the reference path re-reads the row k
//!   times via per-class axpy), and a single-pass [`logits_fast`]
//!   computing all k logits per row in one tile sweep (the reference
//!   softmax path makes k separate full-row `dot_f32` passes).
//!
//! ## Tolerance contract
//!
//! `fast ≡ reference` within a pinned per-op bound, *not* bit-exactly:
//! `mul_add` rounds once where `a*b + c` rounds twice, and the blocked
//! accumulation orders differ. The property tests in
//! `rust/tests/kernel_equivalence.rs` pin the bound per op across sizes
//! 1..~300 (every remainder-lane shape) against an f64 shadow
//! computation; a full training run under `--kernels fast` converges to
//! the same error targets as `reference` (sweep smoke in
//! `rust/tests/sweep_integration.rs`). `reference` itself is re-pinned
//! bit-exact against the raw `linalg` entry points here and against the
//! golden traces in `rust/tests/golden_traces.rs`.
//!
//! `mul_add` lowers to a hardware FMA only when the build enables it
//! (`RUSTFLAGS="-C target-cpu=native"` or `-C target-feature=+fma`);
//! without the feature it would lower to a libm call and *lose* the
//! race, so [`fma32`]/[`fma64`] fall back to `a*b + c` at compile time.
//! Either lowering satisfies the tolerance contract; a given build is
//! internally deterministic (same binary → same bits), which keeps the
//! sim ≡ real equivalence intact *within* a kernel set.
//!
//! The kernel choice never ships over the wire: the dist `Assign` frame
//! (wire v4) does not negotiate kernels, so `RunConfig::validate`
//! rejects `fast` × `--runtime dist` instead of silently downgrading a
//! remote worker to `reference` (see DESIGN.md §11).
//!
//! ## Adding a kernel set (~30 LoC)
//!
//! 1. implement the op set here (`*_myset` functions);
//! 2. add a variant to [`KernelSpec`] plus arms in `name()`/`parse()`
//!    and every dispatch method;
//! 3. add a `KernelInfo` and register it in [`REGISTRY`];
//! 4. document the set in DESIGN.md §11 (the analysis `registry` rule
//!    fails the build until every registered name is documented) and
//!    pin its tolerance in `rust/tests/kernel_equivalence.rs`.
//!
//! Config JSON (`"kernels": "fast"`), `train --kernels`, the sweep
//! `kernels` axis (`/krn-*` group keys), `anytime-sgd list`, and
//! `Trainer::builder().kernels(..)` all resolve through the registry.

use super::Matrix;
use crate::ser::Value;
use anyhow::{anyhow, bail, Result};

/// Registry entry: identity and lookup metadata for one kernel set.
pub struct KernelInfo {
    /// Canonical registry key (CLI/JSON name).
    pub name: &'static str,
    /// Accepted alternate names.
    pub aliases: &'static [&'static str],
    /// One-line description for `anytime-sgd list`.
    pub about: &'static str,
    /// Whether the set reproduces the golden float-op sequence bit for
    /// bit (only `reference` does; everything else is tolerance-pinned).
    pub bit_exact: bool,
}

/// The `reference` registry entry.
pub const REFERENCE_INFO: KernelInfo = KernelInfo {
    name: "reference",
    aliases: &["ref", "golden"],
    about: "golden float-op sequence; every bit-exactness pin runs through it (default)",
    bit_exact: true,
};

/// The `fast` registry entry.
pub const FAST_INFO: KernelInfo = KernelInfo {
    name: "fast",
    aliases: &["opt"],
    about: "FMA + 8-lane unrolled dot/axpy, fused cache-blocked sgd_update, single-pass logits",
    bit_exact: false,
};

/// Every registered kernel set. Order is display order for
/// `anytime-sgd list`.
pub static REGISTRY: &[&KernelInfo] = &[&REFERENCE_INFO, &FAST_INFO];

/// Resolve a kernel-set name (canonical or alias) to its registry entry.
pub fn lookup(name: &str) -> Result<&'static KernelInfo> {
    REGISTRY
        .iter()
        .find(|i| i.name == name || i.aliases.contains(&name))
        .copied()
        .ok_or_else(|| anyhow!("unknown kernel set `{name}` (available: {})", names().join(", ")))
}

/// Registry entry for a spec (infallible: every variant is registered).
pub fn info(spec: KernelSpec) -> &'static KernelInfo {
    REGISTRY
        .iter()
        .find(|i| i.name == spec.name())
        .copied()
        .unwrap_or_else(|| unreachable!("unregistered kernel spec {spec:?}"))
}

/// Canonical names, registry order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|i| i.name).collect()
}

/// Whether `name` resolves (canonical or alias).
pub fn exists(name: &str) -> bool {
    lookup(name).is_ok()
}

/// Which kernel set the numeric core dispatches through — the
/// config-level selector, threaded through JSON, the CLI, sweep grids,
/// and the trainer builder. The hot loop holds the spec by value and
/// dispatches per op via a two-arm match the optimizer resolves per
/// call site.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelSpec {
    /// The golden float-op sequence (default; all bit-exactness pins).
    #[default]
    Reference,
    /// The optimized set (FMA, multi-accumulator, cache-blocked fusion).
    Fast,
}

impl KernelSpec {
    /// Canonical registry name.
    pub fn name(self) -> &'static str {
        match self {
            KernelSpec::Reference => "reference",
            KernelSpec::Fast => "fast",
        }
    }

    /// Parse a CLI/JSON name (canonical or alias) through the registry.
    pub fn parse(name: &str) -> Result<Self> {
        let info = lookup(name)?;
        Ok(match info.name {
            "reference" => KernelSpec::Reference,
            "fast" => KernelSpec::Fast,
            other => unreachable!("registry entry `{other}` has no spec arm"),
        })
    }

    /// From a config JSON value: a bare name string (`"fast"`) or an
    /// object with a `kind` field (`{"kind": "fast"}`).
    pub fn from_json(v: &Value) -> Result<Self> {
        if let Some(name) = v.as_str() {
            return Self::parse(name);
        }
        if v.as_obj().is_some() {
            let kind =
                v.get_str("kind").ok_or_else(|| anyhow!("kernels object needs a `kind` name"))?;
            return Self::parse(kind);
        }
        bail!("kernels must be a name string or an object with `kind`")
    }

    /// Config JSON form (the canonical name).
    pub fn to_json(self) -> Value {
        Value::Str(self.name().to_string())
    }

    /// Config-level validation hook (kept for symmetry with the other
    /// spec enums; no kernel set currently carries parameters).
    pub fn validate(self) -> Result<()> {
        Ok(())
    }

    /// Whether this set reproduces the golden float-op sequence.
    pub fn bit_exact(self) -> bool {
        info(self).bit_exact
    }

    // ------------------------------------------------------- dispatch

    /// f64-accumulated dot product (norms, metrics).
    #[inline]
    pub fn dot(self, a: &[f32], b: &[f32]) -> f64 {
        match self {
            KernelSpec::Reference => super::dot(a, b),
            KernelSpec::Fast => dot_fast(a, b),
        }
    }

    /// f32-accumulated dot product (the per-sample residual/logit op).
    #[inline]
    pub fn dot_f32(self, a: &[f32], b: &[f32]) -> f32 {
        match self {
            KernelSpec::Reference => super::dot_f32(a, b),
            KernelSpec::Fast => dot_f32_fast(a, b),
        }
    }

    /// `y += alpha * x`.
    #[inline]
    pub fn axpy(self, alpha: f32, x: &[f32], y: &mut [f32]) {
        match self {
            KernelSpec::Reference => super::axpy(alpha, x, y),
            KernelSpec::Fast => axpy_fast(alpha, x, y),
        }
    }

    /// Fused minibatch SGD update (see [`super::sgd_update`] for the
    /// factored-gradient contract).
    #[inline]
    pub fn sgd_update(
        self,
        a: &Matrix,
        rows: &[u32],
        coeff: &[f32],
        classes: usize,
        scale: f32,
        x: &mut [f32],
    ) {
        match self {
            KernelSpec::Reference => super::sgd_update(a, rows, coeff, classes, scale, x),
            KernelSpec::Fast => sgd_update_fast(a, rows, coeff, classes, scale, x),
        }
    }

    /// All-class logits of one sample: `out[c] = row · x[c*d..(c+1)*d]`
    /// for a class-major parameter (`d = row.len()`, `k = out.len()`).
    #[inline]
    pub fn logits(self, row: &[f32], x: &[f32], out: &mut [f32]) {
        match self {
            KernelSpec::Reference => logits_reference(row, x, out),
            KernelSpec::Fast => logits_fast(row, x, out),
        }
    }
}

// ---------------------------------------------------------- fast set

/// Column-tile width for the cache-blocked fast kernels: 512 f32 = 2 KiB
/// per slice, so a row tile plus k = 4 class tiles (10 KiB) sit in L1
/// together with room to spare.
const TILE: usize = 512;

/// Fused multiply-add that is an FMA instruction when the build enables
/// the target feature and a plain `a*b + c` otherwise — `mul_add`
/// without hardware FMA lowers to a libm call, which would make the
/// "fast" set slower than `reference`.
#[inline(always)]
fn fma32(a: f32, b: f32, c: f32) -> f32 {
    if cfg!(target_feature = "fma") {
        a.mul_add(b, c)
    } else {
        a * b + c
    }
}

/// f64 twin of [`fma32`].
#[inline(always)]
fn fma64(a: f64, b: f64, c: f64) -> f64 {
    if cfg!(target_feature = "fma") {
        a.mul_add(b, c)
    } else {
        a * b + c
    }
}

/// `fast` f64-accumulated dot: 8 independent accumulator lanes (the
/// reference [`super::dot`] runs 4) with FMA. f32 products widen to f64
/// exactly, so the only difference from reference is accumulation order.
#[inline]
pub fn dot_fast(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f64; 8];
    for c in 0..chunks {
        let i = c * 8;
        let (xs, ys) = (&a[i..i + 8], &b[i..i + 8]);
        for l in 0..8 {
            acc[l] = fma64(xs[l] as f64, ys[l] as f64, acc[l]);
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * 8..n {
        s = fma64(a[i] as f64, b[i] as f64, s);
    }
    s
}

/// `fast` f32 dot: the reference 8-lane shape with each lane's
/// multiply-accumulate fused. Without hardware FMA this is bit-identical
/// to [`super::dot_f32`]; with it, each lane rounds once instead of
/// twice (≤ 1 ulp per step, covered by the tolerance pin).
#[inline]
pub fn dot_f32_fast(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        let (xs, ys) = (&a[i..i + 8], &b[i..i + 8]);
        for l in 0..8 {
            acc[l] = fma32(xs[l], ys[l], acc[l]);
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * 8..n {
        s = fma32(a[i], b[i], s);
    }
    s
}

/// `fast` `y += alpha * x`: explicit 8-wide chunks (autovectorizes to
/// full-width vector FMAs) plus a scalar remainder. Elementwise, so
/// fast-vs-reference differs by at most the single/double rounding of
/// each element's multiply-add.
#[inline]
pub fn axpy_fast(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    for c in 0..chunks {
        let i = c * 8;
        let xs = &x[i..i + 8];
        let ys = &mut y[i..i + 8];
        for l in 0..8 {
            ys[l] = fma32(alpha, xs[l], ys[l]);
        }
    }
    for i in chunks * 8..n {
        y[i] = fma32(alpha, x[i], y[i]);
    }
}

/// `fast` fused SGD update. For `classes == 1` it is the reference
/// per-row loop with the FMA axpy. For `classes > 1` the reference path
/// re-reads each minibatch row `k` times (one full-length axpy per
/// class); here each row is walked once per cache-blocked column tile
/// and all k class-major slices are updated while the row tile is hot
/// in L1 — the row's memory traffic drops from `k·d` to `d` reads.
pub fn sgd_update_fast(
    a: &Matrix,
    rows: &[u32],
    coeff: &[f32],
    classes: usize,
    scale: f32,
    x: &mut [f32],
) {
    let d = a.cols();
    debug_assert!(classes >= 1);
    debug_assert_eq!(x.len(), classes * d);
    debug_assert_eq!(coeff.len(), rows.len() * classes);
    if classes == 1 {
        for (i, &r) in rows.iter().enumerate() {
            axpy_fast(scale * coeff[i], a.row(r as usize), x);
        }
        return;
    }
    for (i, &r) in rows.iter().enumerate() {
        let row = a.row(r as usize);
        let cs = &coeff[i * classes..(i + 1) * classes];
        let mut j0 = 0;
        while j0 < d {
            let j1 = (j0 + TILE).min(d);
            let rt = &row[j0..j1];
            for (c, &cc) in cs.iter().enumerate() {
                axpy_fast(scale * cc, rt, &mut x[c * d + j0..c * d + j1]);
            }
            j0 = j1;
        }
    }
}

/// Reference all-class logits: k separate full-row [`super::dot_f32`]
/// passes — exactly the float-op sequence the softmax objective ran
/// before the dispatch layer existed (the bit-exactness contract).
#[inline]
pub fn logits_reference(row: &[f32], x: &[f32], out: &mut [f32]) {
    let d = row.len();
    debug_assert_eq!(x.len(), out.len() * d);
    for (c, o) in out.iter_mut().enumerate() {
        *o = super::dot_f32(row, &x[c * d..(c + 1) * d]);
    }
}

/// `fast` all-class logits: one sweep over the row in cache-blocked
/// column tiles, accumulating every class's partial dot while the row
/// tile is hot in L1 — the row is read from memory once instead of k
/// times.
pub fn logits_fast(row: &[f32], x: &[f32], out: &mut [f32]) {
    let d = row.len();
    let k = out.len();
    debug_assert_eq!(x.len(), k * d);
    out.fill(0.0);
    let mut j0 = 0;
    while j0 < d {
        let j1 = (j0 + TILE).min(d);
        let rt = &row[j0..j1];
        for (c, o) in out.iter_mut().enumerate() {
            *o += dot_f32_fast(rt, &x[c * d + j0..c * d + j1]);
        }
        j0 = j1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [KernelSpec; 2] = [KernelSpec::Reference, KernelSpec::Fast];

    #[test]
    fn registry_names_unique_and_resolvable() {
        let names = names();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), names.len(), "duplicate registry names");
        for info in REGISTRY {
            assert!(exists(info.name));
            assert!(!info.about.is_empty());
            for alias in info.aliases {
                assert_eq!(lookup(alias).unwrap().name, info.name, "alias {alias}");
                assert!(!names.contains(alias), "alias {alias} shadows a canonical name");
            }
        }
        assert_eq!(names, vec!["reference", "fast"]);
        assert!(lookup("turbo").unwrap_err().to_string().contains("available"));
    }

    #[test]
    fn specs_parse_and_round_trip_json() {
        for spec in ALL {
            assert_eq!(KernelSpec::parse(spec.name()).unwrap(), spec);
            assert_eq!(KernelSpec::from_json(&spec.to_json()).unwrap(), spec);
            let obj = Value::obj(vec![("kind", spec.to_json())]);
            assert_eq!(KernelSpec::from_json(&obj).unwrap(), spec);
            spec.validate().unwrap();
            assert_eq!(info(spec).name, spec.name());
        }
        assert_eq!(KernelSpec::default(), KernelSpec::Reference);
        assert_eq!(KernelSpec::parse("ref").unwrap(), KernelSpec::Reference);
        assert_eq!(KernelSpec::parse("golden").unwrap(), KernelSpec::Reference);
        assert_eq!(KernelSpec::parse("opt").unwrap(), KernelSpec::Fast);
        assert!(KernelSpec::parse("turbo").is_err());
        assert!(KernelSpec::from_json(&Value::Num(3.0)).is_err());
        assert!(KernelSpec::from_json(&Value::obj(vec![("k", Value::Num(2.0))])).is_err());
        // Only reference carries the bit-exactness flag.
        assert!(KernelSpec::Reference.bit_exact());
        assert!(!KernelSpec::Fast.bit_exact());
    }

    #[test]
    fn reference_dispatch_is_bit_identical_to_the_raw_entry_points() {
        let a: Vec<f32> = (0..133).map(|i| (i as f32) * 0.17 - 11.0).collect();
        let b: Vec<f32> = (0..133).map(|i| (i as f32) * -0.05 + 3.0).collect();
        let k = KernelSpec::Reference;
        assert_eq!(k.dot(&a, &b).to_bits(), crate::linalg::dot(&a, &b).to_bits());
        assert_eq!(k.dot_f32(&a, &b).to_bits(), crate::linalg::dot_f32(&a, &b).to_bits());
        let mut y1 = b.clone();
        let mut y2 = b.clone();
        k.axpy(0.37, &a, &mut y1);
        crate::linalg::axpy(0.37, &a, &mut y2);
        assert_eq!(
            y1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y2.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // Reference logits = the per-class dot_f32 loop, bit for bit.
        let d = 19;
        let classes = 3;
        let row: Vec<f32> = (0..d).map(|i| (i as f32).sin()).collect();
        let x: Vec<f32> = (0..classes * d).map(|i| (i as f32).cos()).collect();
        let mut got = vec![0.0f32; classes];
        k.logits(&row, &x, &mut got);
        for c in 0..classes {
            let want = crate::linalg::dot_f32(&row, &x[c * d..(c + 1) * d]);
            assert_eq!(got[c].to_bits(), want.to_bits(), "class {c}");
        }
    }

    #[test]
    fn fast_ops_track_an_f64_shadow_across_remainder_sizes() {
        // Smoke-level bound here; the full per-op property pins live in
        // rust/tests/kernel_equivalence.rs.
        for n in [1usize, 7, 8, 9, 63, 64, 65, 300] {
            let a: Vec<f32> = (0..n).map(|i| ((i * 37 + 11) % 97) as f32 * 0.021 - 1.0).collect();
            let b: Vec<f32> = (0..n).map(|i| ((i * 53 + 5) % 89) as f32 * 0.017 - 0.7).collect();
            let exact: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            let mag: f64 = a.iter().zip(&b).map(|(x, y)| (*x as f64 * *y as f64).abs()).sum();
            let tol = 1e-4 * (1.0 + mag);
            assert!((dot_f32_fast(&a, &b) as f64 - exact).abs() <= tol, "dot_f32 n={n}");
            assert!((dot_fast(&a, &b) - exact).abs() <= 1e-9 * (1.0 + mag), "dot n={n}");
            let mut y = b.clone();
            axpy_fast(0.31, &a, &mut y);
            for i in 0..n {
                let want = 0.31f64 * a[i] as f64 + b[i] as f64;
                assert!((y[i] as f64 - want).abs() <= 1e-6 * (1.0 + want.abs()), "axpy n={n} i={i}");
            }
        }
    }

    #[test]
    fn fast_sgd_update_and_logits_match_reference_within_tolerance() {
        let d = 70; // not a multiple of the 8-lane width or the tile
        let k = 4;
        let m = 12;
        let mut data = vec![0.0f32; m * d];
        for (i, v) in data.iter_mut().enumerate() {
            *v = ((i * 29 + 13) % 101) as f32 * 0.02 - 1.0;
        }
        let a = Matrix::from_vec(m, d, data);
        let rows: Vec<u32> = (0..8u32).map(|i| (i * 3) % m as u32).collect();
        let coeff: Vec<f32> = (0..rows.len() * k).map(|i| (i as f32) * 0.07 - 1.1).collect();
        let scale = -0.013f32;
        let mut x_ref: Vec<f32> = (0..k * d).map(|i| (i as f32) * 0.003).collect();
        let mut x_fast = x_ref.clone();
        crate::linalg::sgd_update(&a, &rows, &coeff, k, scale, &mut x_ref);
        sgd_update_fast(&a, &rows, &coeff, k, scale, &mut x_fast);
        for i in 0..k * d {
            let diff = (x_ref[i] as f64 - x_fast[i] as f64).abs();
            assert!(diff <= 1e-4 * (1.0 + x_ref[i].abs() as f64), "x[{i}]: {diff}");
        }
        let mut l_ref = vec![0.0f32; k];
        let mut l_fast = vec![0.0f32; k];
        logits_reference(a.row(3), &x_ref, &mut l_ref);
        logits_fast(a.row(3), &x_ref, &mut l_fast);
        for c in 0..k {
            let diff = (l_ref[c] as f64 - l_fast[c] as f64).abs();
            assert!(diff <= 1e-4 * (1.0 + l_ref[c].abs() as f64), "logit {c}: {diff}");
        }
    }
}
