//! Small dense linear solvers (f64) — Gaussian elimination with partial
//! pivoting, plus a minimum-norm least-squares fallback via normal
//! equations. Used by the gradient-coding decoder (systems are N×N with
//! N = worker count, so dense O(n³) is plenty).

/// Solve `A x = b` for square `A` (row-major, n×n) by Gaussian
/// elimination with partial pivoting. Returns `None` if singular to
/// working precision.
pub fn solve(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    let mut m = a.to_vec();
    let mut rhs = b.to_vec();
    for col in 0..n {
        // Pivot.
        let mut piv = col;
        let mut best = m[col * n + col].abs();
        for r in col + 1..n {
            let v = m[r * n + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-12 {
            return None;
        }
        if piv != col {
            for j in 0..n {
                m.swap(col * n + j, piv * n + j);
            }
            rhs.swap(col, piv);
        }
        // Eliminate below.
        let d = m[col * n + col];
        for r in col + 1..n {
            let f = m[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for j in col..n {
                m[r * n + j] -= f * m[col * n + j];
            }
            rhs[r] -= f * rhs[col];
        }
    }
    // Back substitution.
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut s = rhs[col];
        for j in col + 1..n {
            s -= m[col * n + j] * x[j];
        }
        x[col] = s / m[col * n + col];
    }
    Some(x)
}

/// Solve a *consistent* (possibly overdetermined) system `A x = b`
/// (A is rows×cols row-major, rows ≥ cols) by Gaussian elimination with
/// full row pivoting across all equations. Avoids the normal equations'
/// condition-number squaring; returns `None` if no pivot is found.
/// The caller should verify the residual — consistency is assumed, not
/// checked here.
pub fn solve_consistent(a: &[f64], b: &[f64], rows: usize, cols: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), rows * cols);
    assert_eq!(b.len(), rows);
    assert!(rows >= cols);
    let mut m = a.to_vec();
    let mut rhs = b.to_vec();
    // Forward elimination: for each column, pivot over ALL remaining rows.
    for col in 0..cols {
        let mut piv = col;
        let mut best = 0.0f64;
        for r in col..rows {
            let v = m[r * cols + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-12 {
            return None;
        }
        if piv != col {
            for j in 0..cols {
                m.swap(col * cols + j, piv * cols + j);
            }
            rhs.swap(col, piv);
        }
        let d = m[col * cols + col];
        for r in col + 1..rows {
            let f = m[r * cols + col] / d;
            if f == 0.0 {
                continue;
            }
            for j in col..cols {
                m[r * cols + j] -= f * m[col * cols + j];
            }
            rhs[r] -= f * rhs[col];
        }
    }
    // Back substitution on the top cols×cols triangle.
    let mut x = vec![0.0; cols];
    for col in (0..cols).rev() {
        let mut s = rhs[col];
        for j in col + 1..cols {
            s -= m[col * cols + j] * x[j];
        }
        x[col] = s / m[col * cols + col];
    }
    Some(x)
}

/// Solve the underdetermined/overdetermined `A x = b` (A is r×c,
/// row-major) in the least-squares sense via normal equations
/// `AᵀA x = Aᵀ b` with Tikhonov jitter for rank deficiency.
pub fn lstsq(a: &[f64], b: &[f64], rows: usize, cols: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), rows * cols);
    assert_eq!(b.len(), rows);
    let mut ata = vec![0.0; cols * cols];
    let mut atb = vec![0.0; cols];
    for i in 0..rows {
        for j in 0..cols {
            let aij = a[i * cols + j];
            if aij == 0.0 {
                continue;
            }
            atb[j] += aij * b[i];
            for k in 0..cols {
                ata[j * cols + k] += aij * a[i * cols + k];
            }
        }
    }
    // Jitter keeps the decode well-posed when the receive set is larger
    // than strictly necessary (redundant rows).
    let trace: f64 = (0..cols).map(|j| ata[j * cols + j]).sum();
    let eps = 1e-10 * (trace / cols as f64).max(1e-300);
    for j in 0..cols {
        ata[j * cols + j] += eps;
    }
    solve(&ata, &atb, cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_known_system() {
        // [2 1; 1 3] x = [5; 10] -> x = [1; 3]
        let a = [2.0, 1.0, 1.0, 3.0];
        let b = [5.0, 10.0];
        let x = solve(&a, &b, 2).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        // [0 1; 1 0] x = [2; 3] -> x = [3; 2]
        let a = [0.0, 1.0, 1.0, 0.0];
        let x = solve(&a, &[2.0, 3.0], 2).unwrap();
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn singular_returns_none() {
        let a = [1.0, 2.0, 2.0, 4.0];
        assert!(solve(&a, &[1.0, 2.0], 2).is_none());
    }

    #[test]
    fn random_round_trip() {
        use crate::rng::Xoshiro256pp;
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        for n in [1usize, 3, 8, 15] {
            let a: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut b = vec![0.0; n];
            for i in 0..n {
                for j in 0..n {
                    b[i] += a[i * n + j] * x_true[j];
                }
            }
            let x = solve(&a, &b, n).unwrap();
            for (got, want) in x.iter().zip(&x_true) {
                assert!((got - want).abs() < 1e-8, "n={n}");
            }
        }
    }

    #[test]
    fn lstsq_overdetermined_consistent() {
        // 3 equations, 2 unknowns, consistent system.
        let a = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let b = [2.0, 3.0, 5.0];
        let x = lstsq(&a, &b, 3, 2).unwrap();
        assert!((x[0] - 2.0).abs() < 1e-5);
        assert!((x[1] - 3.0).abs() < 1e-5);
    }
}
