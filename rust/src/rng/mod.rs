//! Deterministic pseudo-random number generation and sampling.
//!
//! The image ships no `rand` crate, so this module is a from-scratch
//! substrate: a [`SplitMix64`] seeder, a [`Xoshiro256pp`] generator
//! (xoshiro256++ 1.0, Blackman & Vigna), and the distributions the
//! paper's workloads need — uniform, Gaussian (Box–Muller), exponential,
//! Pareto, log-normal, and categorical.
//!
//! # Determinism contract
//!
//! Every stochastic choice in the system (data synthesis, minibatch
//! indices, straggler delays, communication times) flows from one root
//! seed through *named splits* ([`Xoshiro256pp::split`]), so whole
//! experiments are bit-reproducible across runs and across thread
//! interleavings: each worker/epoch pair derives its own independent
//! stream up front rather than sharing a mutable generator.

mod distributions;

pub use distributions::{Categorical, Distribution, Exponential, LogNormal, Normal, Pareto, Uniform};

/// SplitMix64 — used to expand a 64-bit seed into xoshiro state and to
/// derive child seeds for named streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new SplitMix64 stream from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — the workhorse generator.
///
/// Fast (sub-ns per draw), 2^256-1 period, passes BigCrush. All sampling
/// in the repo goes through this type.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion (the reference seeding procedure).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        // All-zero state is the one forbidden state; SplitMix64 cannot
        // produce four consecutive zeros, but guard anyway.
        if s == [0, 0, 0, 0] {
            Self { s: [0x9E3779B97F4A7C15, 1, 2, 3] }
        } else {
            Self { s }
        }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3])).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1): 53 mantissa bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift with
    /// rejection (unbiased).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Derive an independent child stream from a name and indices.
    ///
    /// Streams are keyed by an FNV-1a hash of `(label, a, b)` mixed with
    /// this stream's *initial-state-independent* seed material. The parent
    /// is not advanced — splits are pure functions of (parent state, key),
    /// which is what makes per-(worker, epoch) streams order-independent.
    pub fn split(&self, label: &str, a: u64, b: u64) -> Xoshiro256pp {
        let mut h: u64 = 0xcbf29ce484222325;
        for &byte in label.as_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h ^= a.wrapping_mul(0x9E3779B97F4A7C15);
        h = h.rotate_left(17);
        h ^= b.wrapping_mul(0xBF58476D1CE4E5B9);
        // Mix with the parent's state so different roots give different
        // children even for identical labels.
        let mix = self.s[0] ^ self.s[1].rotate_left(13) ^ self.s[2].rotate_left(29) ^ self.s[3].rotate_left(43);
        Xoshiro256pp::seed_from_u64(h ^ mix)
    }

    /// Standard normal draw (Box–Muller, one value per call; the spare is
    /// discarded to keep `split`/replay semantics simple).
    pub fn normal(&mut self) -> f64 {
        // Avoid u1 == 0 (log(0)).
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill `out` with i.i.d. N(0,1) f32 values.
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        // Box–Muller pairs: consume both outputs for throughput.
        let mut i = 0;
        while i + 1 < out.len() {
            let u1 = loop {
                let u = self.next_f64();
                if u > 0.0 {
                    break u;
                }
            };
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            out[i] = (r * theta.cos()) as f32;
            out[i + 1] = (r * theta.sin()) as f32;
            i += 2;
        }
        if i < out.len() {
            out[i] = self.normal() as f32;
        }
    }

    /// Sample `k` indices uniformly without replacement from `[0, n)`
    /// (partial Fisher–Yates over an index map; O(k) memory for k ≪ n
    /// would need a hash map — we keep the simple O(n) scratch since the
    /// call sites reuse a scratch buffer).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize, scratch: &mut Vec<usize>) -> Vec<usize> {
        assert!(k <= n);
        scratch.clear();
        scratch.extend(0..n);
        for i in 0..k {
            let j = i + self.index(n - i);
            scratch.swap(i, j);
        }
        scratch[..k].to_vec()
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public-domain
        // splitmix64.c reference implementation.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Seed 0 first output of splitmix64 is 0xE220A8397B1DCDAF.
        assert_eq!(a, 0xE220A8397B1DCDAF);
    }

    #[test]
    fn xoshiro_is_deterministic() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_different_seeds_differ() {
        let mut a = Xoshiro256pp::seed_from_u64(1);
        let mut b = Xoshiro256pp::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_unbiased_small_bound() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        let mut counts = [0usize; 3];
        let n = 90_000;
        for _ in 0..n {
            counts[r.next_below(3) as usize] += 1;
        }
        for &c in &counts {
            let expected = n / 3;
            assert!((c as i64 - expected as i64).unsigned_abs() < 1500, "counts={counts:?}");
        }
    }

    #[test]
    fn split_streams_are_independent_and_stable() {
        let root = Xoshiro256pp::seed_from_u64(99);
        let mut w0e0 = root.split("worker", 0, 0);
        let mut w0e0_again = root.split("worker", 0, 0);
        let mut w1e0 = root.split("worker", 1, 0);
        let mut w0e1 = root.split("worker", 0, 1);
        assert_eq!(w0e0.next_u64(), w0e0_again.next_u64());
        let x = w0e0.next_u64();
        assert_ne!(x, w1e0.next_u64());
        assert_ne!(x, w0e1.next_u64());
        // Label matters.
        let mut d = root.split("delay", 0, 0);
        assert_ne!(root.split("worker", 0, 0).next_u64(), d.next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(5);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn fill_normal_f32_moments_odd_len() {
        let mut r = Xoshiro256pp::seed_from_u64(8);
        let mut buf = vec![0.0f32; 100_001];
        r.fill_normal_f32(&mut buf);
        let mean: f64 = buf.iter().map(|&x| x as f64).sum::<f64>() / buf.len() as f64;
        let var: f64 =
            buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.03);
    }

    #[test]
    fn sample_without_replacement_unique_and_in_range() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let mut scratch = Vec::new();
        let s = r.sample_without_replacement(100, 30, &mut scratch);
        assert_eq!(s.len(), 30);
        let mut seen = std::collections::HashSet::new();
        for &i in &s {
            assert!(i < 100);
            assert!(seen.insert(i), "duplicate {i}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
