//! Sampling distributions over [`Xoshiro256pp`].
//!
//! Straggler delay models (`crate::straggler`) compose these: e.g. the
//! EC2-like finishing-time model is a mixture of a [`LogNormal`] body and
//! a [`Pareto`] tail.

use super::Xoshiro256pp;

/// A sampleable univariate distribution.
pub trait Distribution {
    /// Draw one sample.
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64;

    /// Analytic mean, if finite (used by tests and by `T` auto-tuning).
    fn mean(&self) -> Option<f64>;
}

/// Uniform on `[lo, hi)`.
#[derive(Clone, Copy, Debug)]
pub struct Uniform {
    pub lo: f64,
    pub hi: f64,
}

impl Uniform {
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(hi >= lo, "Uniform: hi < lo");
        Self { lo, hi }
    }
}

impl Distribution for Uniform {
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }
    fn mean(&self) -> Option<f64> {
        Some(0.5 * (self.lo + self.hi))
    }
}

/// Gaussian `N(mu, sigma^2)`.
#[derive(Clone, Copy, Debug)]
pub struct Normal {
    pub mu: f64,
    pub sigma: f64,
}

impl Normal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(sigma >= 0.0, "Normal: sigma < 0");
        Self { mu, sigma }
    }
}

impl Distribution for Normal {
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        self.mu + self.sigma * rng.normal()
    }
    fn mean(&self) -> Option<f64> {
        Some(self.mu)
    }
}

/// Exponential with rate `lambda` (mean `1/lambda`).
#[derive(Clone, Copy, Debug)]
pub struct Exponential {
    pub lambda: f64,
}

impl Exponential {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0, "Exponential: lambda <= 0");
        Self { lambda }
    }
}

impl Distribution for Exponential {
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        // Inverse CDF; 1 - U avoids ln(0).
        -(1.0 - rng.next_f64()).ln() / self.lambda
    }
    fn mean(&self) -> Option<f64> {
        Some(1.0 / self.lambda)
    }
}

/// Pareto (Type I): support `[xm, inf)`, shape `alpha`.
///
/// `alpha <= 1` has infinite mean — exactly the heavy-tail regime the
/// "tail at scale" literature ascribes to shared-tenancy stragglers.
#[derive(Clone, Copy, Debug)]
pub struct Pareto {
    pub xm: f64,
    pub alpha: f64,
}

impl Pareto {
    pub fn new(xm: f64, alpha: f64) -> Self {
        assert!(xm > 0.0 && alpha > 0.0, "Pareto: xm, alpha must be > 0");
        Self { xm, alpha }
    }
}

impl Distribution for Pareto {
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        self.xm / (1.0 - rng.next_f64()).powf(1.0 / self.alpha)
    }
    fn mean(&self) -> Option<f64> {
        (self.alpha > 1.0).then(|| self.alpha * self.xm / (self.alpha - 1.0))
    }
}

/// Log-normal: `exp(N(mu, sigma^2))`.
#[derive(Clone, Copy, Debug)]
pub struct LogNormal {
    pub mu: f64,
    pub sigma: f64,
}

impl LogNormal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(sigma >= 0.0);
        Self { mu, sigma }
    }

    /// Construct from a target median and p90/median ratio — the natural
    /// parameterization when fitting "bulk finishes in 10–40 s".
    pub fn from_median_p90(median: f64, p90: f64) -> Self {
        assert!(p90 > median && median > 0.0);
        // p90 = median * exp(sigma * z90), z90 ≈ 1.2815515655446004.
        let sigma = (p90 / median).ln() / 1.2815515655446004;
        Self { mu: median.ln(), sigma }
    }
}

impl Distribution for LogNormal {
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        (self.mu + self.sigma * rng.normal()).exp()
    }
    fn mean(&self) -> Option<f64> {
        Some((self.mu + 0.5 * self.sigma * self.sigma).exp())
    }
}

/// Categorical over arbitrary weights (normalized internally).
#[derive(Clone, Debug)]
pub struct Categorical {
    cdf: Vec<f64>,
}

impl Categorical {
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty());
        assert!(weights.iter().all(|&w| w >= 0.0), "negative weight");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all-zero weights");
        let mut cdf = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            acc += w / total;
            cdf.push(acc);
        }
        *cdf.last_mut().unwrap() = 1.0; // close rounding gap
        Self { cdf }
    }

    /// Draw a category index.
    pub fn sample_index(&self, rng: &mut Xoshiro256pp) -> usize {
        let u = rng.next_f64();
        // Binary search the CDF.
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => (i + 1).min(self.cdf.len() - 1),
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

impl Distribution for Categorical {
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        self.sample_index(rng) as f64
    }
    fn mean(&self) -> Option<f64> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical_mean<D: Distribution>(d: &D, n: usize, seed: u64) -> f64 {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let d = Uniform::new(2.0, 6.0);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        for _ in 0..5000 {
            let x = d.sample(&mut rng);
            assert!((2.0..6.0).contains(&x));
        }
        assert!((empirical_mean(&d, 100_000, 2) - 4.0).abs() < 0.02);
    }

    #[test]
    fn exponential_mean() {
        let d = Exponential::new(0.5);
        assert_eq!(d.mean(), Some(2.0));
        assert!((empirical_mean(&d, 200_000, 3) - 2.0).abs() < 0.03);
    }

    #[test]
    fn pareto_mean_finite_alpha() {
        let d = Pareto::new(1.0, 3.0);
        assert_eq!(d.mean(), Some(1.5));
        assert!((empirical_mean(&d, 400_000, 4) - 1.5).abs() < 0.05);
    }

    #[test]
    fn pareto_heavy_tail_has_no_mean() {
        assert_eq!(Pareto::new(1.0, 0.9).mean(), None);
        // And empirically produces extreme values.
        let d = Pareto::new(1.0, 0.9);
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let max = (0..100_000).map(|_| d.sample(&mut rng)).fold(0.0f64, f64::max);
        assert!(max > 1000.0, "max={max}");
    }

    #[test]
    fn lognormal_from_median_p90() {
        let d = LogNormal::from_median_p90(20.0, 40.0);
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let mut xs: Vec<f64> = (0..100_000).map(|_| d.sample(&mut rng)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        let p90 = xs[(xs.len() as f64 * 0.9) as usize];
        assert!((median - 20.0).abs() < 0.5, "median={median}");
        assert!((p90 - 40.0).abs() < 1.0, "p90={p90}");
    }

    #[test]
    fn normal_wraps_moments() {
        let d = Normal::new(-3.0, 2.0);
        assert!((empirical_mean(&d, 200_000, 7) + 3.0).abs() < 0.02);
    }

    #[test]
    fn categorical_frequencies() {
        let d = Categorical::new(&[1.0, 2.0, 7.0]);
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[d.sample_index(&mut rng)] += 1;
        }
        assert!((counts[0] as f64 / n as f64 - 0.1).abs() < 0.01);
        assert!((counts[1] as f64 / n as f64 - 0.2).abs() < 0.01);
        assert!((counts[2] as f64 / n as f64 - 0.7).abs() < 0.01);
    }

    #[test]
    #[should_panic]
    fn categorical_rejects_all_zero() {
        Categorical::new(&[0.0, 0.0]);
    }
}
