//! Gradient Coding (Tandon et al., ICML'17) — the coded-computation
//! baseline of Fig. 4.
//!
//! Setup: N workers, data in N blocks, block b placed on workers
//! `{b, b−1, …, b−S} mod N` (the same cyclic placement as Table I, which
//! is exactly Tandon's cyclic repetition support). Each epoch every
//! worker computes the *full* gradient of each of its S+1 blocks and
//! sends one coded vector
//!
//! ```text
//!     c_v = Σ_b  B[v, b] · g_b
//! ```
//!
//! The master, after hearing from any set χ with |χ| ≥ N−S workers,
//! finds coefficients a with  Σ_{v∈χ} a_v B[v,·] = 1ᵀ  and recovers the
//! full gradient  Σ_b g_b = Σ_v a_v c_v.
//!
//! Construction: Tandon's Algorithm 1 — draw `H ∈ R^{S×N}` Gaussian with
//! columns summing to zero and fill each row's cyclic support so that
//! `H Bᵀ = 0` with a leading 1; their Theorem guarantees every
//! (N−S)-subset of rows spans `1ᵀ` with probability 1.
//! [`GradientCode::new`] additionally *verifies* decodability
//! (exhaustively for small N) and resamples on failure.

use crate::linalg::solve_consistent;
use crate::rng::Xoshiro256pp;

/// The code matrix and its parameters.
#[derive(Clone, Debug)]
pub struct GradientCode {
    n: usize,
    s: usize,
    /// Row-major N×N; row v is worker v's encoding vector (support =
    /// blocks of worker v).
    b: Vec<f64>,
}

impl GradientCode {
    /// Build a decodable code for (n, s); `s < n`.
    pub fn new(n: usize, s: usize, seed: u64) -> Self {
        assert!(s < n, "gradient coding requires S < N");
        let mut rng = Xoshiro256pp::seed_from_u64(seed).split("gradient-code", n as u64, s as u64);
        // s = 0 degenerates to plain distributed GD: identity code.
        if s == 0 {
            let mut b = vec![0.0; n * n];
            for v in 0..n {
                b[v * n + v] = 1.0;
            }
            return Self { n, s, b };
        }
        // Tandon et al. Algorithm 1: draw H ∈ R^{s×n} Gaussian with
        // columns summing to zero, then fill each row's support so that
        // H Bᵀ = 0 with a leading 1 — this guarantees 1ᵀ lies in the
        // span of every (N−S)-subset of rows (their Theorem 2) w.p. 1.
        'attempt: for _ in 0..64 {
            let mut h = vec![0.0f64; s * n];
            for r in 0..s {
                let mut row_sum = 0.0;
                for cidx in 0..n - 1 {
                    let v = rng.normal();
                    h[r * n + cidx] = v;
                    row_sum += v;
                }
                h[r * n + (n - 1)] = -row_sum;
            }
            let mut b = vec![0.0; n * n];
            for v in 0..n {
                // Support j0=v, j1..js = v+1..v+s (mod n). Solve
                // H[:, j1..js] · w = −H[:, j0]; row = [1, w].
                let mut sub = vec![0.0f64; s * s];
                let mut rhs = vec![0.0f64; s];
                for r in 0..s {
                    rhs[r] = -h[r * n + v];
                    for k in 1..=s {
                        sub[r * s + (k - 1)] = h[r * n + (v + k) % n];
                    }
                }
                let Some(w) = crate::linalg::solve(&sub, &rhs, s) else {
                    continue 'attempt;
                };
                b[v * n + v] = 1.0;
                for k in 1..=s {
                    b[v * n + (v + k) % n] = w[k - 1];
                }
            }
            let code = Self { n, s, b };
            if code.verify() {
                return code;
            }
        }
        panic!("failed to construct a decodable gradient code for n={n} s={s}");
    }

    pub fn n(&self) -> usize {
        self.n
    }
    pub fn s(&self) -> usize {
        self.s
    }

    /// Blocks worker v encodes over (cyclic support, matches Table I).
    pub fn blocks_of(&self, v: usize) -> Vec<usize> {
        (0..=self.s).map(|k| (v + k) % self.n).collect()
    }

    /// Check decodability from every contiguous-loss pattern and a sample
    /// of random (N−S)-subsets (exhaustive for small N).
    fn verify(&self) -> bool {
        let k = self.n - self.s;
        // All subsets when feasible, else sampled.
        let subsets = enumerate_or_sample_subsets(self.n, k, 2000);
        subsets.iter().all(|sub| self.decode_coeffs(sub).is_some())
    }

    /// Worker-side encode: `c_v = Σ_b B[v,b] g_b`. `block_grads[i]` is
    /// the gradient of the i-th block in `blocks_of(v)` order.
    pub fn encode(&self, v: usize, block_grads: &[Vec<f32>]) -> Vec<f32> {
        let blocks = self.blocks_of(v);
        assert_eq!(block_grads.len(), blocks.len());
        let d = block_grads[0].len();
        let mut out = vec![0.0f32; d];
        for (i, &blk) in blocks.iter().enumerate() {
            let w = self.b[v * self.n + blk] as f32;
            crate::linalg::axpy(w, &block_grads[i], &mut out);
        }
        out
    }

    /// Decoding coefficients for a received worker set: find `a` with
    /// `Σ_v a_v B[v,·] = 1ᵀ`. Returns None if not decodable.
    pub fn decode_coeffs(&self, received: &[usize]) -> Option<Vec<f64>> {
        if received.len() < self.n - self.s {
            return None;
        }
        // Solve Bᵀ_χ a = 1: n equations, |χ| unknowns, least squares.
        let rows = self.n;
        let cols = received.len();
        let mut mat = vec![0.0; rows * cols];
        for (j, &v) in received.iter().enumerate() {
            for blk in 0..self.n {
                mat[blk * cols + j] = self.b[v * self.n + blk];
            }
        }
        let ones = vec![1.0; rows];
        let a = solve_consistent(&mat, &ones, rows, cols)?;
        // Verify the solution actually reconstructs 1ᵀ (lstsq always
        // returns *something*; consistency is the decodability test).
        for blk in 0..self.n {
            let got: f64 = received
                .iter()
                .enumerate()
                .map(|(j, &v)| a[j] * self.b[v * self.n + blk])
                .sum();
            if (got - 1.0).abs() > 1e-6 {
                return None;
            }
        }
        Some(a)
    }

    /// Master-side decode: full gradient `Σ_b g_b` from coded vectors.
    pub fn decode(&self, received: &[(usize, Vec<f32>)]) -> Option<Vec<f32>> {
        let ids: Vec<usize> = received.iter().map(|(v, _)| *v).collect();
        let a = self.decode_coeffs(&ids)?;
        let d = received[0].1.len();
        let mut out = vec![0.0f32; d];
        let mut acc = vec![0.0f64; d];
        for ((_, c), &av) in received.iter().zip(a.iter()) {
            for (s, &cv) in acc.iter_mut().zip(c.iter()) {
                *s += av * cv as f64;
            }
        }
        for (o, &s) in out.iter_mut().zip(acc.iter()) {
            *o = s as f32;
        }
        Some(out)
    }
}

/// All k-subsets of [0,n) if the count is small, else `samples` random
/// ones (plus all contiguous-loss patterns, the adversarial cases for
/// cyclic codes).
fn enumerate_or_sample_subsets(n: usize, k: usize, samples: usize) -> Vec<Vec<usize>> {
    fn choose(n: usize, k: usize) -> usize {
        let mut r = 1usize;
        for i in 0..k {
            r = r.saturating_mul(n - i) / (i + 1);
        }
        r
    }
    let mut out = Vec::new();
    if choose(n, k) <= 4096 {
        // Exhaustive enumeration (lexicographic).
        let mut idx: Vec<usize> = (0..k).collect();
        loop {
            out.push(idx.clone());
            // Advance.
            let mut i = k;
            loop {
                if i == 0 {
                    return out;
                }
                i -= 1;
                if idx[i] != i + n - k {
                    break;
                }
            }
            idx[i] += 1;
            for j in i + 1..k {
                idx[j] = idx[j - 1] + 1;
            }
        }
    }
    // Contiguous-loss patterns: drop s consecutive workers.
    for start in 0..n {
        let lost: Vec<usize> = (0..n - k).map(|i| (start + i) % n).collect();
        out.push((0..n).filter(|v| !lost.contains(v)).collect());
    }
    let mut rng = Xoshiro256pp::seed_from_u64(0xC0DE).split("subsets", n as u64, k as u64);
    let mut scratch = Vec::new();
    for _ in 0..samples {
        let mut s = rng.sample_without_replacement(n, k, &mut scratch);
        s.sort_unstable();
        out.push(s);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_code_for_s0() {
        let code = GradientCode::new(5, 0, 1);
        let a = code.decode_coeffs(&[0, 1, 2, 3, 4]).unwrap();
        for &c in &a {
            assert!((c - 1.0).abs() < 1e-9);
        }
        // s=0 cannot tolerate any loss.
        assert!(code.decode_coeffs(&[0, 1, 2, 3]).is_none());
    }

    #[test]
    fn decodes_from_any_n_minus_s_subset() {
        for (n, s) in [(5, 1), (6, 2), (10, 2), (10, 3), (7, 3)] {
            let code = GradientCode::new(n, s, 2);
            let subsets = enumerate_or_sample_subsets(n, n - s, 500);
            for sub in subsets {
                assert!(
                    code.decode_coeffs(&sub).is_some(),
                    "n={n} s={s}: subset {sub:?} not decodable"
                );
            }
        }
    }

    #[test]
    fn too_few_workers_not_decodable() {
        let code = GradientCode::new(6, 2, 3);
        assert!(code.decode_coeffs(&[0, 1, 2]).is_none());
    }

    #[test]
    fn encode_decode_recovers_gradient_sum() {
        use crate::rng::Xoshiro256pp;
        let (n, s, d) = (6usize, 2usize, 40usize);
        let code = GradientCode::new(n, s, 4);
        // Random per-block "gradients".
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let blocks: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut g = vec![0.0f32; d];
                rng.fill_normal_f32(&mut g);
                g
            })
            .collect();
        let want: Vec<f32> = (0..d).map(|j| blocks.iter().map(|g| g[j]).sum()).collect();

        // Lose workers 1 and 4 (any 2 with s=2).
        let received: Vec<(usize, Vec<f32>)> = [0usize, 2, 3, 5]
            .iter()
            .map(|&v| {
                let grads: Vec<Vec<f32>> =
                    code.blocks_of(v).iter().map(|&b| blocks[b].clone()).collect();
                (v, code.encode(v, &grads))
            })
            .collect();
        let got = code.decode(&received).unwrap();
        for j in 0..d {
            assert!((got[j] - want[j]).abs() < 1e-3, "j={j}: {} vs {}", got[j], want[j]);
        }
    }

    #[test]
    fn construction_deterministic_per_seed() {
        let a = GradientCode::new(8, 2, 7);
        let b = GradientCode::new(8, 2, 7);
        assert_eq!(a.b, b.b);
    }

    #[test]
    fn support_matches_table_one() {
        let code = GradientCode::new(6, 2, 1);
        assert_eq!(code.blocks_of(0), vec![0, 1, 2]);
        assert_eq!(code.blocks_of(5), vec![5, 0, 1]);
        // Off-support entries are exactly zero.
        for v in 0..6 {
            let blocks = code.blocks_of(v);
            for blk in 0..6 {
                let entry = code.b[v * 6 + blk];
                if blocks.contains(&blk) {
                    assert!(entry != 0.0);
                } else {
                    assert_eq!(entry, 0.0);
                }
            }
        }
    }
}
