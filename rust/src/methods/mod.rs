//! Method-specific machinery beyond the shared coordinator loop.
//!
//! The per-epoch protocols of all five methods (anytime, generalized,
//! sync, FNB, gradient coding) live in `crate::coordinator`; this module
//! holds the pieces with real algorithmic content of their own —
//! currently the Gradient Coding code construction/encoder/decoder.

pub mod gradient_coding;
