//! The paper's convergence analysis (§III) as executable bounds.
//!
//! Given problem constants (L, σ, D, G) and a realized step profile
//! `q_1..q_N`, this module computes:
//!
//! * [`expected_distance_bound`] — Theorem 1's bound on E[F(x) − F(x*)],
//! * [`variance_bound`] — Theorem 2's bound on V[F(x) − F(x*)],
//! * [`optimal_lambda`] — Theorem 3's variance-minimizing weights
//!   λ_v = q_v / Σ q (also exposed as the general constrained-QP solver
//!   so tests can verify Theorem 3 against brute force),
//! * [`corollary4_bound`] — the 1/Q variance decay of Corollary 4,
//! * [`high_prob_bound`] — Theorem 5 / Corollary 6's deviation bound,
//! * [`generalized_lambda`] — eq. (13) for the §V worker-side blend.
//!
//! The `figures theory` harness checks these against empirical runs.

/// Problem constants of the analysis (§III-A).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Constants {
    /// Gradient Lipschitz constant L (eq. 3).
    pub big_l: f64,
    /// Gradient-noise bound σ: E‖∇f − ∇F‖² ≤ σ².
    pub sigma: f64,
    /// Domain radius D: D² = max d(x, u).
    pub big_d: f64,
    /// Gradient bound G: ‖∇f‖ ≤ G.
    pub big_g: f64,
    /// Initial suboptimality F(x₀) − F(x*).
    pub f0_gap: f64,
}

impl Constants {
    /// Estimate constants for a least-squares problem with i.i.d. N(0,1)
    /// design of m rows, d cols — used to instantiate the paper schedule
    /// (`theory::constants` in DESIGN.md §6).
    pub fn for_synthetic_linreg(m: usize, d: usize) -> Self {
        let (m, d) = (m as f64, d as f64);
        // Per-sample f_k = (a·x − y)²: ∇f = 2a(a·x − y). Over the unit-ish
        // domain ‖x − x*‖ ≤ √d: |a·(x−x*)| ~ √d ⇒ L ≈ 2E‖a‖² ≈ 2d.
        let big_l = 2.0 * d;
        let big_d = d.sqrt();
        let sigma = 2.0 * d; // gradient noise scale ~ L
        let big_g = 2.0 * d * big_d / m.sqrt().max(1.0) + 2.0 * d;
        Self { big_l, sigma, big_d, big_g, f0_gap: d * m / m }
    }

    /// σ/D — the schedule coefficient the artifacts consume.
    pub fn sigma_over_d(&self) -> f64 {
        self.sigma / self.big_d
    }
}

/// Theorem 1: E[F(x) − F(x*)] ≤ Σ_v (λ_v/q_v)(F₀ + LD² + 2σD√q_v).
pub fn expected_distance_bound(c: &Constants, lambda: &[f64], q: &[usize]) -> f64 {
    assert_eq!(lambda.len(), q.len());
    lambda
        .iter()
        .zip(q.iter())
        .filter(|(_, &qv)| qv > 0)
        .map(|(&lv, &qv)| {
            let qv = qv as f64;
            lv / qv * (c.f0_gap + c.big_l * c.big_d * c.big_d + 2.0 * c.sigma * c.big_d * qv.sqrt())
        })
        .sum()
}

/// Theorem 2: V[F(x) − F(x*)] ≤ 2σ²D²(G²/σ² + 2) Σ λ_v²/q_v.
pub fn variance_bound(c: &Constants, lambda: &[f64], q: &[usize]) -> f64 {
    let pref = 2.0 * c.sigma * c.sigma * c.big_d * c.big_d
        * (c.big_g * c.big_g / (c.sigma * c.sigma) + 2.0);
    pref
        * lambda
            .iter()
            .zip(q.iter())
            .filter(|(_, &qv)| qv > 0)
            .map(|(&lv, &qv)| lv * lv / qv as f64)
            .sum::<f64>()
}

/// Theorem 3: λ_v = q_v / Σ q — the variance-bound minimizer subject to
/// Σλ = 1, λ ≥ 0. Workers with q_v = 0 (outside χ) get λ_v = 0
/// (Algorithm 1, step 13).
pub fn optimal_lambda(q: &[usize]) -> Vec<f64> {
    let total: usize = q.iter().sum();
    if total == 0 {
        return vec![0.0; q.len()];
    }
    q.iter().map(|&qv| qv as f64 / total as f64).collect()
}

/// General minimizer of Σ λ_v²·r_v s.t. Σλ=1, λ≥0 (r_v > 0): the
/// closed form is λ_v ∝ 1/r_v. Exposed so tests can confirm Theorem 3
/// is this QP's solution with r_v = 1/q_v (up to the paper's constant).
pub fn qp_min_weighted_sq(r: &[f64]) -> Vec<f64> {
    let inv: Vec<f64> = r.iter().map(|&rv| if rv > 0.0 { 1.0 / rv } else { 0.0 }).collect();
    let s: f64 = inv.iter().sum();
    if s == 0.0 {
        return vec![0.0; r.len()];
    }
    inv.iter().map(|&i| i / s).collect()
}

/// Corollary 4: with Theorem-3 weights the variance bound collapses to
/// 2σ²D²(G²/σ²+2)/Q, Q = Σ q_v.
pub fn corollary4_bound(c: &Constants, q: &[usize]) -> f64 {
    let total: usize = q.iter().sum();
    if total == 0 {
        return f64::INFINITY;
    }
    2.0 * c.sigma * c.sigma * c.big_d * c.big_d
        * (c.big_g * c.big_g / (c.sigma * c.sigma) + 2.0)
        / total as f64
}

/// Theorem 5: with probability ≥ 1−δ,
/// F(x)−F(x*)−E[·] ≤ γ·2GD(G/σ+2)·log(1/δ)·√(1 + 18·V/log(1/δ))
/// with γ = max_v λ_v/q_v and V the Theorem-2 bound (the paper's (11)
/// written through (59)'s variance form).
pub fn high_prob_bound(c: &Constants, lambda: &[f64], q: &[usize], delta: f64) -> f64 {
    assert!((0.0..1.0).contains(&delta) && delta > 0.0);
    let gamma = lambda
        .iter()
        .zip(q.iter())
        .filter(|(_, &qv)| qv > 0)
        .map(|(&lv, &qv)| lv / qv as f64)
        .fold(0.0f64, f64::max);
    let v = variance_bound(c, lambda, q);
    let logd = (1.0 / delta).ln();
    gamma * 2.0 * c.big_g * c.big_d * (c.big_g / c.sigma + 2.0) * logd
        * (1.0 + 18.0 * v / logd).sqrt()
}

/// §V eq. (13): worker-side blending factor
/// λ_vt = Σq / (q̄_v + Σq), where q̄_v is the steps the worker completed
/// during the communication window.
pub fn generalized_lambda(sum_q: usize, qbar_v: usize) -> f64 {
    if sum_q == 0 && qbar_v == 0 {
        return 1.0;
    }
    sum_q as f64 / (qbar_v + sum_q) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn consts() -> Constants {
        Constants { big_l: 2.0, sigma: 1.0, big_d: 3.0, big_g: 4.0, f0_gap: 5.0 }
    }

    #[test]
    fn optimal_lambda_is_proportional_and_normalized() {
        let lam = optimal_lambda(&[100, 50, 0, 50]);
        assert_eq!(lam, vec![0.5, 0.25, 0.0, 0.25]);
        assert!((lam.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(optimal_lambda(&[0, 0]), vec![0.0, 0.0]);
    }

    #[test]
    fn theorem3_minimizes_variance_bound() {
        // Brute-force check on the 2-worker simplex.
        let c = consts();
        let q = [120usize, 30];
        let best = optimal_lambda(&q);
        let vb_best = variance_bound(&c, &best, &q);
        for i in 0..=100 {
            let l0 = i as f64 / 100.0;
            let vb = variance_bound(&c, &[l0, 1.0 - l0], &q);
            assert!(vb + 1e-12 >= vb_best, "λ=({l0},{}) beats Theorem 3", 1.0 - l0);
        }
    }

    #[test]
    fn qp_solver_agrees_with_theorem3() {
        let q = [120usize, 30, 60];
        // r_v ∝ 1/q_v ⇒ QP solution ∝ q_v.
        let r: Vec<f64> = q.iter().map(|&qv| 1.0 / qv as f64).collect();
        let qp = qp_min_weighted_sq(&r);
        let th3 = optimal_lambda(&q);
        for (a, b) in qp.iter().zip(th3.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn corollary4_matches_theorem2_at_optimum() {
        let c = consts();
        let q = [40usize, 10, 30];
        let lam = optimal_lambda(&q);
        let v = variance_bound(&c, &lam, &q);
        let c4 = corollary4_bound(&c, &q);
        assert!((v - c4).abs() < 1e-9 * c4, "{v} vs {c4}");
    }

    #[test]
    fn variance_decays_with_total_work() {
        let c = consts();
        let small = corollary4_bound(&c, &[10, 10]);
        let big = corollary4_bound(&c, &[100, 100]);
        assert!((small / big - 10.0).abs() < 1e-9);
    }

    #[test]
    fn expected_bound_favors_more_steps() {
        // More steps per worker with the same weights lowers the
        // per-worker 1/q_v·(F0 + LD²) term share but grows √q — the
        // bound's shape; check monotone pieces make sense.
        let c = consts();
        let b1 = expected_distance_bound(&c, &[1.0], &[10]);
        let b2 = expected_distance_bound(&c, &[1.0], &[1000]);
        // Dominant term 2σD√q/q = 2σD/√q shrinks with q.
        assert!(b2 < b1);
    }

    #[test]
    fn high_prob_bound_tightens_with_delta_and_q() {
        let c = consts();
        let q = [50usize, 50];
        let lam = optimal_lambda(&q);
        let loose = high_prob_bound(&c, &lam, &q, 0.5);
        let tight = high_prob_bound(&c, &lam, &q, 0.01);
        assert!(tight > loose, "smaller δ ⇒ larger bound");
        let q_big = [500usize, 500];
        let lam_big = optimal_lambda(&q_big);
        assert!(high_prob_bound(&c, &lam_big, &q_big, 0.1) < high_prob_bound(&c, &lam, &q, 0.1));
    }

    #[test]
    fn generalized_lambda_matches_eq13() {
        assert_eq!(generalized_lambda(100, 0), 1.0);
        assert_eq!(generalized_lambda(100, 100), 0.5);
        assert_eq!(generalized_lambda(0, 0), 1.0);
        assert!((generalized_lambda(300, 100) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn synthetic_constants_sane() {
        let c = Constants::for_synthetic_linreg(50_000, 200);
        assert!(c.big_l > 0.0 && c.sigma > 0.0 && c.big_d > 0.0 && c.big_g > 0.0);
        assert!((c.sigma_over_d() - c.sigma / c.big_d).abs() < 1e-12);
    }
}
